"""Executor offload: blocking (synchronous) externals must parallelize.

The engine dispatches sync externals on a per-runtime ThreadPoolExecutor
(``loop.run_in_executor``) by default, so the dominant real-world case —
blocking SDK clients — overlaps exactly like async externals, while the
lock protocol, trace ordering, and sequential semantics are preserved.
"""

import threading
import time

import pytest

from repro.core import (
    ExternalCallError,
    OffloadPolicy,
    equivalent,
    offload_policy,
    poppy,
    readonly,
    recording,
    sequential,
    sequential_mode,
    unordered,
)
from repro.core import ai as ai_mod
from repro.core.ai import (
    SimulatedBackend,
    embed_sync,
    llm,
    llm_sync,
    use_backend,
    use_sync_clients,
)
from repro.core.trace import Trace


class Overlap:
    """Thread-safe concurrency meter for blocking externals."""

    def __init__(self):
        self.lock = threading.Lock()
        self.cur = 0
        self.max = 0

    def __enter__(self):
        with self.lock:
            self.cur += 1
            self.max = max(self.max, self.cur)
        return self

    def __exit__(self, *exc):
        with self.lock:
            self.cur -= 1
        return False


# ---------------------------------------------------------------------------
# the headline: blocking externals overlap


def make_fetch(meter, delay=0.05):
    @unordered
    def fetch(i):
        with meter:
            time.sleep(delay)
        return f"r{i}"
    return fetch


@poppy
def _gather4(fetch):
    a = fetch(0)
    b = fetch(1)
    c = fetch(2)
    d = fetch(3)
    return (a, b, c, d)


def test_blocking_unordered_externals_overlap():
    meter = Overlap()
    fetch = make_fetch(meter)
    t0 = time.perf_counter()
    out = _gather4(fetch)
    dt = time.perf_counter() - t0
    assert out == ("r0", "r1", "r2", "r3")
    assert meter.max >= 3, f"blocking calls serialized (max overlap {meter.max})"
    assert dt < 0.15, f"no overlap: took {dt:.3f}s (sequential would be 0.2s)"


def test_blocking_externals_match_sequential_mode():
    meter = Overlap()
    fetch = make_fetch(meter)
    with recording() as t_poppy:
        r_poppy = _gather4(fetch)
    with recording() as t_plain, sequential_mode():
        r_plain = _gather4(fetch)
    assert r_poppy == r_plain
    ok, why = equivalent(t_plain, t_poppy)
    assert ok, why


def test_offloaded_external_runs_on_worker_thread():
    @unordered
    def where():
        return threading.current_thread().name

    @unordered(offload="inline")
    def where_inline():
        return threading.current_thread().name

    @poppy
    def prog():
        return (where(), where_inline())

    offloaded, inline = prog()
    assert offloaded.startswith("poppy-offload")
    assert inline == threading.main_thread().name


# ---------------------------------------------------------------------------
# configuration: per-annotation and per-runtime policy


def test_inline_annotation_serializes():
    meter = Overlap()

    @unordered(offload="inline")
    def fetch(i):
        with meter:
            time.sleep(0.03)
        return i

    @poppy
    def prog():
        a = fetch(0)
        b = fetch(1)
        c = fetch(2)
        return (a, b, c)

    t0 = time.perf_counter()
    assert prog() == (0, 1, 2)
    dt = time.perf_counter() - t0
    assert meter.max == 1
    assert dt > 0.08, f"inline externals overlapped: {dt:.3f}s"


def test_offload_policy_inline_serializes():
    meter = Overlap()
    fetch = make_fetch(meter, delay=0.03)
    with offload_policy(mode="inline"):
        t0 = time.perf_counter()
        out = _gather4(fetch)
        dt = time.perf_counter() - t0
    assert out == ("r0", "r1", "r2", "r3")
    assert meter.max == 1
    assert dt > 0.1


def test_offload_policy_caps_workers():
    meter = Overlap()
    fetch = make_fetch(meter, delay=0.04)
    with offload_policy(max_workers=2):
        out = _gather4(fetch)
    assert out == ("r0", "r1", "r2", "r3")
    assert meter.max <= 2


def test_offload_policy_validation():
    with pytest.raises(ValueError):
        OffloadPolicy(mode="fiber")
    with pytest.raises(ValueError):
        OffloadPolicy(max_workers=0)
    with pytest.raises(ValueError):
        OffloadPolicy(process_workers=0)
    assert OffloadPolicy(mode="process").mode == "process"


# ---------------------------------------------------------------------------
# lock protocol across threads


def test_sequential_blocking_externals_keep_program_order():
    order = []

    @sequential
    def step(i):
        time.sleep(0.01 * (5 - i))  # later steps are faster
        order.append(i)
        return i

    @poppy
    def prog():
        for i in range(5):
            step(i)
        return None

    prog()
    assert order == [0, 1, 2, 3, 4]


def test_readonly_window_with_blocking_externals():
    state = {"v": 0}

    @sequential
    def write(v):
        time.sleep(0.01)
        state["v"] = v
        return None

    @readonly
    def read(tag):
        time.sleep(0.01)
        return state["v"]

    @poppy
    def prog():
        write(1)
        a = read("a")
        b = read("b")
        write(2)
        c = read("c")
        return (a, b, c)

    assert prog() == (1, 1, 2)
    with sequential_mode():
        assert prog() == (1, 1, 2)


def test_mixed_async_and_blocking_externals():
    import asyncio

    meter = Overlap()

    @unordered
    async def a_fetch(i):
        await asyncio.sleep(0.05)
        return f"a{i}"

    @unordered
    def s_fetch(i):
        with meter:
            time.sleep(0.05)
        return f"s{i}"

    @poppy
    def prog():
        w = a_fetch(0)
        x = s_fetch(1)
        y = a_fetch(2)
        z = s_fetch(3)
        return (w, x, y, z)

    t0 = time.perf_counter()
    assert prog() == ("a0", "s1", "a2", "s3")
    dt = time.perf_counter() - t0
    assert dt < 0.15, f"async/sync mix serialized: {dt:.3f}s"


# ---------------------------------------------------------------------------
# the ambient bridge: blocking components and externals calling components


def test_llm_sync_components_overlap_and_match_plain():
    @poppy
    def ask(topics):
        out = tuple()
        for t in topics:
            out += (llm_sync(f"about {t}"),)
        return out

    be = SimulatedBackend(base_s=0.05)
    with use_backend(be):
        t0 = time.perf_counter()
        r = ask(("a", "b", "c", "d"))
        dt = time.perf_counter() - t0
    assert be.max_in_flight >= 2, "blocking LLM calls serialized"
    assert dt < 0.25

    be2 = SimulatedBackend(base_s=0.05)
    with use_backend(be2), sequential_mode():
        assert ask(("a", "b", "c", "d")) == r


def test_embed_sync_roundtrip():
    be = SimulatedBackend(base_s=0.01)
    with use_backend(be):
        v = embed_sync("hello")
    assert isinstance(v, tuple) and len(v) == 8


def test_blocking_external_may_call_async_component():
    # a worker thread has no running loop, so the annotation wrapper drives
    # the coroutine to completion there; ambient backend resolves through
    # the propagated context
    @unordered
    def summarize(t):
        return ai_mod.llm(f"sum {t}")

    @poppy
    def prog():
        a = summarize("x")
        b = summarize("y")
        return (a, b)

    be = SimulatedBackend(base_s=0.03)
    with use_backend(be):
        r = prog()
    assert len(r) == 2 and all(isinstance(s, str) for s in r)


def test_use_sync_clients_swaps_and_restores():
    @poppy
    def ask(topics):
        out = tuple()
        for t in topics:
            out += (llm(f"topic {t}"),)
        return out

    be = SimulatedBackend(base_s=0.04)
    with use_backend(be), use_sync_clients():
        r_poppy = ask(("a", "b", "c"))
        with sequential_mode():
            r_plain = ask(("a", "b", "c"))
    assert r_poppy == r_plain
    assert be.max_in_flight >= 2
    # restored: back to the async client
    import repro.core.registry as registry
    from repro.core.controllers import unwrap_external
    assert registry.is_async_callable(unwrap_external(llm))


def test_run_blocking_rejects_running_loop():
    import asyncio

    async def inner():
        with pytest.raises(RuntimeError, match="running event loop"):
            llm_sync("boom")

    asyncio.run(inner())


# ---------------------------------------------------------------------------
# trace thread-safety


def test_trace_recording_is_thread_safe():
    tr = Trace()
    n_threads, per_thread = 8, 200

    def pound():
        for i in range(per_thread):
            tr.record_direct(f"call{i}", "unordered")

    threads = [threading.Thread(target=pound) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events) == n_threads * per_thread
    seqs = [e.seq_no for e in tr.events]
    assert len(set(seqs)) == len(seqs), "duplicate dispatch sequence numbers"


# ---------------------------------------------------------------------------
# failure propagation through the executor


def test_offloaded_failure_wraps_and_propagates_promptly():
    @unordered
    def boom():
        raise RuntimeError("kaput")

    @unordered
    def slow(i):
        time.sleep(0.3)
        return i

    @poppy
    def prog():
        a = slow(1)
        b = boom()
        return (a, b)

    t0 = time.perf_counter()
    with pytest.raises(ExternalCallError) as ei:
        prog()
    dt = time.perf_counter() - t0
    assert isinstance(ei.value.original, RuntimeError)
    assert dt < 2.0, f"failure propagation waited for stragglers: {dt:.1f}s"
