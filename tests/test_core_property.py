"""Property-based differential testing: hypothesis generates random programs
in the supported fragment; PopPy execution must match plain-Python execution
in results, observable effect order, and ≡_A trace equivalence — the
system-level invariant of paper Prop. 1."""

import asyncio
import textwrap

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    equivalent,
    poppy,
    recording,
    sequential,
    sequential_mode,
    unordered,
    readonly,
)

INT_VARS = ["x0", "x1", "x2"]
TUP_VARS = ["t0", "t1"]


class World:
    def __init__(self):
        self.reset()
        w = self

        @unordered
        async def ext_u(s):
            await asyncio.sleep((hash(s) % 3) / 1000.0)
            return f"u({s})"

        @sequential
        def ext_seq(v):
            w.out.append(("seq", v))
            return None

        @sequential
        def ext_w(v):
            w.cell = v
            w.out.append(("w", v))
            return None

        @readonly
        def ext_ro():
            w.out.append(("ro", w.cell))
            return w.cell

        self.ns = {"ext_u": ext_u, "ext_seq": ext_seq, "ext_w": ext_w,
                   "ext_ro": ext_ro}

    def reset(self):
        self.out = []
        self.cell = 0


# ---------------------------------------------------------------------------
# program generator (source-level)

int_expr = st.deferred(lambda: st.one_of(
    st.integers(-5, 9).map(str),
    st.sampled_from(INT_VARS),
    st.tuples(int_leaf, st.sampled_from(["+", "-", "*"]), int_leaf).map(
        lambda t: f"({t[0]} {t[1]} {t[2]})"),
))
int_leaf = st.one_of(st.integers(-5, 9).map(str), st.sampled_from(INT_VARS))

cond_expr = st.tuples(
    st.sampled_from(INT_VARS),
    st.sampled_from(["<", ">", "<=", ">=", "==", "!="]),
    st.integers(-2, 6),
).map(lambda t: f"{t[0]} {t[1]} {t[2]}")

str_expr = st.one_of(
    st.sampled_from(INT_VARS).map(lambda v: f'f"s{{{v}}}"'),
    st.sampled_from(TUP_VARS).map(lambda v: f'f"n{{len({v})}}"'),
)


def _indent(block):
    return textwrap.indent("\n".join(block), "    ")


simple_stmt = st.one_of(
    st.tuples(st.sampled_from(INT_VARS), int_expr).map(
        lambda t: f"{t[0]} = {t[1]}"),
    st.tuples(st.sampled_from(INT_VARS), int_expr).map(
        lambda t: f"{t[0]} += {t[1]}"),
    st.tuples(st.sampled_from(TUP_VARS), str_expr).map(
        lambda t: f"{t[0]} += (ext_u({t[1]}),)"),
    st.sampled_from(INT_VARS).map(lambda v: f"ext_seq(f\"v{{{v}}}\")"),
    st.sampled_from(TUP_VARS).map(lambda v: f"ext_seq(f\"t{{{v}}}\")"),
    int_expr.map(lambda e: f"ext_w({e})"),
    st.sampled_from(INT_VARS).map(lambda v: f"{v} = ext_ro()"),
    st.tuples(st.sampled_from(TUP_VARS), str_expr).map(
        lambda t: f"{t[0]} = {t[0]} + (ext_u({t[1]}),)"),
)


def stmt_block(depth):
    if depth <= 0:
        return st.lists(simple_stmt, min_size=1, max_size=4)
    sub = stmt_block(depth - 1)
    if_stmt = st.tuples(cond_expr, sub, sub).map(
        lambda t: [f"if {t[0]}:", _indent(t[1]), "else:", _indent(t[2])])
    for_stmt = st.tuples(st.integers(0, 4), st.sampled_from("ijk"), sub).map(
        lambda t: [f"for {t[1]} in range({t[0]}):", _indent(t[2])])
    for_tup = st.tuples(st.sampled_from(TUP_VARS), sub).map(
        lambda t: [f"for s in {t[0]}:", _indent(t[1])])
    compound = st.one_of(if_stmt, for_stmt, for_tup)
    return st.lists(st.one_of(simple_stmt.map(lambda s: [s]), compound),
                    min_size=1, max_size=4).map(
        lambda blocks: [line for b in blocks for line in
                        (b if isinstance(b, list) else [b])])


programs = stmt_block(2).map(lambda body: (
    "def prog(x0, x1, x2):\n"
    "    t0 = ()\n"
    "    t1 = ('seed',)\n"
    + _indent(body) + "\n"
    "    return (x0, x1, x2, t0, t1)\n"))


@settings(max_examples=40, deadline=None)
@given(src=programs, args=st.tuples(st.integers(-3, 5), st.integers(-3, 5),
                                    st.integers(-3, 5)))
def test_random_program_equivalence(src, args):
    world = World()
    ns = dict(world.ns)
    exec(compile(src, "<generated>", "exec"), ns)
    fn = poppy(ns["prog"], strict=True)
    # make source retrievable for the compiler
    fn._bezoar = None
    import repro.core.frontend as fe
    import ast as ast_mod

    # compile directly from the generated source (inspect can't see it)
    tree = ast_mod.parse(src)
    fdef = tree.body[0]
    fc = fe._FuncCompiler(fdef.name, fdef.args, fdef.body, parent=None,
                          source_file="<generated>", lineno=1,
                          defaults_from=ns["prog"])
    bf = fc.compile()
    from repro.core.lower import lower_function
    fn._lfunc = lower_function(bf, ns["prog"])
    fn._compiled = True

    world.reset()
    with recording() as t_plain, sequential_mode():
        r_plain = fn(*args)
    plain_out = list(world.out)

    world.reset()
    with recording() as t_poppy:
        r_poppy = fn(*args)
    poppy_out = list(world.out)

    assert r_plain == r_poppy, f"\n{src}\nresults: {r_plain} vs {r_poppy}"
    assert plain_out == poppy_out, (
        f"\n{src}\neffects: {plain_out} vs {poppy_out}")
    ok, why = equivalent(t_plain, t_poppy)
    assert ok, f"\n{src}\ntraces: {why}"
