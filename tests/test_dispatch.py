"""repro.dispatch tests: routing policies, admission backpressure, cache
hit/coalescing determinism, retry/hedge reliability under injected
failures, and the differential invariant — dispatch preserves results and
trace equivalence vs. direct backend calls and vs. sequential_mode()."""

import asyncio
import time

import pytest

from repro.core import equivalent, poppy, recording, sequential_mode
from repro.core.ai import (
    Backend,
    SimulatedBackend,
    embed,
    llm,
    use_backend,
    use_dispatcher,
)
from repro.dispatch import (
    AdmissionPolicy,
    AdmissionRejected,
    Dispatcher,
    HedgePolicy,
    ResultCache,
    RetryPolicy,
    TokenBucket,
    make_router,
)


def fast_backend(**kw):
    return SimulatedBackend(time_scale=0.02, **kw)


async def gen(d, prompt, **kw):
    kw.setdefault("max_tokens", 8)
    kw.setdefault("temperature", 0.0)
    kw.setdefault("stop", None)
    return await d.generate(prompt, **kw)


# -- injected-failure / injected-latency backends ---------------------------


class FlakyBackend(Backend):
    """Fails the first ``fail_first`` generate calls, then succeeds."""

    def __init__(self, fail_first, inner=None):
        self.fail_first = fail_first
        self.inner = inner or fast_backend()
        self.attempts = 0

    async def generate(self, prompt, *, max_tokens, temperature, stop):
        self.attempts += 1
        if self.attempts <= self.fail_first:
            await asyncio.sleep(0.005)    # fail like a network call: late
            raise ConnectionError(f"injected failure #{self.attempts}")
        return await self.inner.generate(
            prompt, max_tokens=max_tokens, temperature=temperature,
            stop=stop)

    async def embed(self, text):
        return await self.inner.embed(text)


class StragglerBackend(Backend):
    """Deterministic straggler: every call stalls ``stall_s``."""

    def __init__(self, stall_s, inner=None):
        self.stall_s = stall_s
        self.inner = inner or fast_backend()
        self.calls = 0

    async def generate(self, prompt, *, max_tokens, temperature, stop):
        self.calls += 1
        await asyncio.sleep(self.stall_s)
        return await self.inner.generate(
            prompt, max_tokens=max_tokens, temperature=temperature,
            stop=stop)

    async def embed(self, text):
        await asyncio.sleep(self.stall_s)
        return await self.inner.embed(text)


# -- routing ----------------------------------------------------------------


def test_weighted_router_distribution():
    r = make_router(["a", "b"], policy="weighted", weights=[3, 1])
    picks = [r.pick().backend for _ in range(40)]
    assert picks.count("a") == 30 and picks.count("b") == 10
    # smooth WRR interleaves rather than bursting
    assert picks[:4].count("a") == 3


def test_least_outstanding_prefers_idle():
    r = make_router(["a", "b"], policy="least_outstanding")
    ra = r.pick()
    ra.begin()                      # a now has one in flight
    assert r.pick().backend != ra.backend
    ra.end()


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        make_router(["a"], policy="round_trip")


def test_dispatcher_balances_replicas():
    b1, b2 = fast_backend(), fast_backend()
    d = Dispatcher([b1, b2])

    async def go():
        await asyncio.gather(*[gen(d, f"p{i}") for i in range(8)])

    asyncio.run(go())
    assert len(b1.calls) == len(b2.calls) == 4


# -- admission control ------------------------------------------------------


def test_concurrency_cap_backpressure():
    be = fast_backend()
    d = Dispatcher([be], admission=AdmissionPolicy(max_concurrency=2))

    async def go():
        return await asyncio.gather(*[gen(d, f"p{i}") for i in range(10)])

    outs = asyncio.run(go())
    assert be.max_in_flight <= 2          # the burst was bounded
    assert len(be.calls) == 10            # ...but everything ran
    direct = [asyncio.run(gen(fast_backend(), f"p{i}")) for i in range(10)]
    assert outs == direct                 # and results are unchanged


def test_token_bucket_paces_requests():
    async def go():
        tb = TokenBucket(rate=200.0, burst=1)
        t0 = time.monotonic()
        for _ in range(5):
            await tb.acquire()
        return time.monotonic() - t0

    # 5 acquires at 200/s with burst 1 ⇒ ≥ 4 inter-token waits of 5 ms
    assert asyncio.run(go()) >= 4 * (1 / 200.0) * 0.8


def test_admission_queue_overflow_sheds_load():
    be = StragglerBackend(0.2)
    d = Dispatcher([be], admission=AdmissionPolicy(
        max_concurrency=1, max_queue=2))

    async def go():
        return await asyncio.gather(
            *[gen(d, f"p{i}") for i in range(6)], return_exceptions=True)

    outs = asyncio.run(go())
    rejected = [o for o in outs if isinstance(o, AdmissionRejected)]
    assert rejected and d.stats.rejected == len(rejected)
    assert any(isinstance(o, str) for o in outs)   # the admitted ones ran


# -- cache + coalescing -----------------------------------------------------


def test_cache_hit_is_deterministic():
    be = fast_backend()
    d = Dispatcher([be], cache=True)

    async def go():
        a = await gen(d, "same prompt")
        b = await gen(d, "same prompt")
        return a, b

    a, b = asyncio.run(go())
    assert a == b
    assert len(be.calls) == 1
    assert d.stats.cache_hits == 1 and d.stats.cache_misses == 1


def test_cache_key_separates_params():
    be = fast_backend()
    d = Dispatcher([be], cache=True)

    async def go():
        a = await gen(d, "p", max_tokens=4)
        b = await gen(d, "p", max_tokens=6)
        return a, b

    asyncio.run(go())
    assert len(be.calls) == 2             # different params ⇒ different key


def test_inflight_coalescing():
    be = fast_backend()
    d = Dispatcher([be], cache=True)

    async def go():
        return await asyncio.gather(*[gen(d, "dup") for _ in range(8)])

    outs = asyncio.run(go())
    assert len(set(outs)) == 1
    assert len(be.calls) == 1             # one dispatch served all eight
    assert d.stats.coalesced == 7


def test_coalesced_failure_propagates():
    be = FlakyBackend(fail_first=100)     # always fails
    d = Dispatcher([be], cache=True)

    async def go():
        return await asyncio.gather(
            *[gen(d, "dup") for _ in range(4)], return_exceptions=True)

    outs = asyncio.run(go())
    assert all(isinstance(o, ConnectionError) for o in outs)
    assert be.attempts == 1               # failure shared, not re-dispatched

    async def retry_after_failure():
        return await gen(d, "dup")

    # failures are not cached: a later call dispatches again
    with pytest.raises(ConnectionError):
        asyncio.run(retry_after_failure())
    assert be.attempts == 2


def test_disk_cache_survives_dispatcher_restart(tmp_path):
    be1 = fast_backend()
    d1 = Dispatcher([be1], cache=dict(disk_dir=tmp_path))

    async def first():
        return await gen(d1, "persist me"), await d1.embed("vec")

    g1, e1 = asyncio.run(first())
    assert isinstance(e1, tuple)

    be2 = fast_backend()
    d2 = Dispatcher([be2], cache=dict(disk_dir=tmp_path))   # fresh process

    async def second():
        return await gen(d2, "persist me"), await d2.embed("vec")

    g2, e2 = asyncio.run(second())
    assert (g1, e1) == (g2, e2)
    assert isinstance(e2, tuple)          # tuple type survives JSON round-trip
    assert len(be2.calls) == 0            # served entirely from disk
    assert d2.stats.disk_hits == 2


def test_sampled_completions_bypass_cache():
    """temperature > 0 means independent draws — never served from cache."""
    be = fast_backend()
    d = Dispatcher([be], cache=True)

    async def go():
        await gen(d, "sample me", temperature=0.8)
        await gen(d, "sample me", temperature=0.8)
        await gen(d, "sample me")             # temperature 0: cacheable
        await gen(d, "sample me")
        return len(be.calls)

    assert asyncio.run(go()) == 3             # 2 sampled + 1 greedy
    assert d.stats.cache_hits == 1


def test_coalesced_waiter_survives_primary_cancellation():
    """Cancelling the first (primary) request must not poison coalesced
    waiters of the same key — they re-dispatch."""
    be = fast_backend()
    d = Dispatcher([be], cache=True)

    async def go():
        primary = asyncio.ensure_future(gen(d, "shared"))
        await asyncio.sleep(0.001)            # let it dispatch
        waiter = asyncio.ensure_future(gen(d, "shared"))
        await asyncio.sleep(0.001)            # let it coalesce
        primary.cancel()
        return await waiter

    out = asyncio.run(go())
    assert out == asyncio.run(gen(fast_backend(), "shared"))


def test_admission_controller_instance_stays_per_replica():
    """Passing a pre-built AdmissionController must not silently share one
    gate across replicas — its policy is applied per backend."""
    from repro.dispatch import AdmissionController
    b1, b2 = fast_backend(), fast_backend()
    ctl = AdmissionController(AdmissionPolicy(max_concurrency=2))
    d = Dispatcher([b1, b2], admission=ctl)

    async def go():
        await asyncio.gather(*[gen(d, f"p{i}") for i in range(12)])

    asyncio.run(go())
    assert b1.max_in_flight <= 2 and b2.max_in_flight <= 2
    # per-replica (not global) cap: both replicas were saturated at once
    assert b1.max_in_flight + b2.max_in_flight == 4


def test_lru_eviction():
    be = fast_backend()
    d = Dispatcher([be], cache=ResultCache(capacity=2))

    async def go():
        await gen(d, "a")
        await gen(d, "b")
        await gen(d, "c")                 # evicts "a"
        await gen(d, "a")                 # miss again
        return len(be.calls)

    assert asyncio.run(go()) == 4


# -- reliability ------------------------------------------------------------


def test_retry_recovers_from_transient_failures():
    be = FlakyBackend(fail_first=2)
    d = Dispatcher([be], retry=RetryPolicy(max_attempts=4, base_s=0.001))
    out = asyncio.run(gen(d, "flaky"))
    assert isinstance(out, str)
    assert be.attempts == 3
    assert d.stats.retries == 2


def test_retry_exhaustion_raises():
    be = FlakyBackend(fail_first=10)
    d = Dispatcher([be], retry=RetryPolicy(max_attempts=3, base_s=0.001))
    with pytest.raises(ConnectionError):
        asyncio.run(gen(d, "flaky"))
    assert be.attempts == 3


def test_backoff_jitter_is_deterministic():
    from repro.dispatch.reliability import backoff_s
    p = RetryPolicy(base_s=0.1, jitter_frac=0.3)
    assert backoff_s(p, 1, "k") == backoff_s(p, 1, "k")
    assert backoff_s(p, 1, "k") != backoff_s(p, 2, "k")
    assert backoff_s(p, 2, "k") <= p.max_backoff_s * (1 + p.jitter_frac)


def test_hedge_beats_straggler():
    slow = StragglerBackend(0.5)
    fast = fast_backend()
    d = Dispatcher([slow, fast], policy="least_outstanding",
                   hedge=HedgePolicy(delay_s=0.05))

    async def go():
        t0 = time.monotonic()
        out = await gen(d, "straggler")
        return out, time.monotonic() - t0

    out, dt = asyncio.run(go())
    # hedge fired, re-routed to the idle fast replica, and won
    assert d.stats.hedges >= 1 and d.stats.hedge_wins >= 1
    assert dt < 0.5
    assert out == asyncio.run(gen(fast_backend(), "straggler"))


def test_hedge_result_matches_unhedged():
    b1, b2 = fast_backend(), fast_backend()
    d = Dispatcher([b1, b2], hedge=HedgePolicy(delay_s=0.001, max_hedges=1))

    async def go():
        return await asyncio.gather(*[gen(d, f"h{i}") for i in range(6)])

    outs = asyncio.run(go())
    direct = [asyncio.run(gen(fast_backend(), f"h{i}")) for i in range(6)]
    assert outs == direct                 # duplicates never change results


# -- differential: dispatch preserves PopPy semantics -----------------------


@poppy
def fanout_app(n):
    summaries = tuple()
    for i in range(n):
        s = llm(f"summarize shard {i % 3}", max_tokens=8)
        summaries += (s,)
    e = embed("query")
    combined = llm(f"combine: {summaries} {e[0]:.3f}", max_tokens=12)
    return combined


def test_default_dispatch_is_transparent():
    """Single backend, cache off ⇒ identical results and call counts to the
    pre-dispatch behavior (the zero-behavior-change guarantee)."""
    be1 = fast_backend()
    with use_backend(be1), recording() as tr1:
        r1 = fanout_app(6)
    be2 = fast_backend()
    with use_backend(be2), sequential_mode(), recording() as tr2:
        r2 = fanout_app(6)
    assert r1 == r2
    assert be1.calls and len(be1.calls) == len(be2.calls)
    ok, why = equivalent(tr1, tr2)
    assert ok, why


def test_dispatch_preserves_sequential_semantics():
    """Full production config (2 replicas, cache, admission, hedging) still
    returns exactly what sequential_mode() over a direct backend returns,
    and cache hits are trace-equivalent to misses."""
    direct = fast_backend()
    with use_backend(direct), sequential_mode():
        expect = fanout_app(6)

    d = Dispatcher([fast_backend(), fast_backend()],
                   cache=True,
                   admission=AdmissionPolicy(max_concurrency=4,
                                             rate=2000.0, burst=8),
                   retry=RetryPolicy(max_attempts=2, base_s=0.001),
                   hedge=HedgePolicy(delay_s=0.5))
    with use_dispatcher(d), recording() as tr_cold:
        r_cold = fanout_app(6)           # cold cache: all misses
    with use_dispatcher(d), recording() as tr_warm:
        r_warm = fanout_app(6)           # warm cache: all hits

    assert r_cold == expect and r_warm == expect
    ok, why = equivalent(tr_cold, tr_warm)
    assert ok, f"cache hits not trace-equivalent to misses: {why}"
    assert d.stats.hit_rate > 0

    # and under sequential_mode through the same dispatcher
    with use_dispatcher(d), sequential_mode():
        assert fanout_app(6) == expect


def test_dispatcher_nests_as_backend():
    """A Dispatcher satisfies the Backend interface, so it can itself be a
    replica of an outer Dispatcher (hierarchical routing)."""
    inner = Dispatcher([fast_backend()], cache=True)
    outer = Dispatcher([inner])
    out = asyncio.run(gen(outer, "nested"))
    assert out == asyncio.run(gen(fast_backend(), "nested"))
