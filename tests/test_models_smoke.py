"""Per-architecture smoke tests: a REDUCED config of the same family runs a
forward pass, a train-style loss+grad step, and a prefill→decode
consistency check on CPU, asserting shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

BATCH, SEQ = 2, 16


def make_batch(cfg, rng):
    tokens = jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "enc_dec":
        batch["encoder_frames"] = jax.random.normal(
            rng, (BATCH, cfg.enc_seq, cfg.d_model), jnp.float32)
    elif cfg.frontend == "patch_stub":
        batch["patch_embeds"] = jax.random.normal(
            rng, (BATCH, 4, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)

    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss_fn, has_aux=True))(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forcing logits at position t must match prefill(≤t−1) +
    decode_step(t) — validates every cache implementation."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    tokens = batch["tokens"]

    full_logits, _ = jax.jit(model.forward)(params, batch)

    split = SEQ - 4
    prompt = {**batch, "tokens": tokens[:, :split]}
    prompt.pop("targets")
    last_logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, capacity=SEQ))(params, prompt)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full_logits[:, split - 1]),
        rtol=2e-4, atol=2e-4)

    decode = jax.jit(model.decode_step)
    for t in range(split, SEQ):
        positions = jnp.full((BATCH,), t, jnp.int32)
        logits, cache = decode(params, cache, tokens[:, t:t + 1], positions)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-4, atol=2e-4,
            err_msg=f"{arch}: decode step {t} diverges from forward")


def test_all_archs_registered():
    assert len(ARCH_IDS) == 10
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.vocab_size > 0 and cfg.num_layers > 0


def test_param_counts_reasonable():
    """Full configs should land near their published parameter counts."""
    expect = {
        "qwen3-14b": (13e9, 16e9),
        "yi-34b": (32e9, 36e9),
        "qwen2.5-32b": (31e9, 35e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "mamba2-2.7b": (2.4e9, 3.1e9),
        "whisper-medium": (0.6e9, 1.1e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "stablelm-3b": (2.5e9, 3.6e9),
        "pixtral-12b": (11e9, 14e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).num_params()
        assert lo <= n <= hi, \
            f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]B"
