"""The paper's benchmark applications as differential tests: every app must
produce identical results, identical ordered output, and ≡_A traces under
PopPy vs standard Python — with real concurrency (simulated latencies)."""

import pytest

from benchmarks.common import bench_app
from repro.core import equivalent, recording, sequential_mode
from repro.core.ai import SimulatedBackend, use_backend


def run_app_both(mod, arg=None):
    be = SimulatedBackend(base_s=0.005, per_token_s=0.0005)
    with use_backend(be), recording() as t1, sequential_mode():
        r1 = mod.run(arg) if arg else mod.run()
    out1 = list(mod.OUT)
    be2 = SimulatedBackend(base_s=0.005, per_token_s=0.0005)
    with use_backend(be2), recording() as t2:
        r2 = mod.run(arg) if arg else mod.run()
    out2 = list(mod.OUT)
    return r1, r2, out1, out2, t1, t2


@pytest.mark.parametrize("app", ["tot", "sot", "dae", "bird", "traq"])
def test_app_differential(app):
    import importlib
    mod = importlib.import_module(f"benchmarks.apps.{app}")
    r1, r2, out1, out2, t1, t2 = run_app_both(mod)
    assert r1 == r2, f"{app}: results differ"
    assert out1 == out2, f"{app}: ordered output differs"
    ok, why = equivalent(t1, t2)
    assert ok, f"{app}: {why}"


@pytest.mark.parametrize("key", [f"C-{i}" for i in (1, 2, 3, 4, 5, 6, 13)])
def test_camel_differential(key):
    from benchmarks.apps import camel
    r1, r2, out1, out2, t1, t2 = run_app_both(camel, key)
    assert r1 == r2
    assert out1 == out2
    ok, why = equivalent(t1, t2)
    assert ok, f"{key}: {why}"


def test_apps_actually_speed_up():
    from benchmarks.apps import sot
    r = bench_app(sot.run, trials=1, scale=0.5)
    assert r["speedup"] > 1.5, f"SoT speedup only {r['speedup']:.2f}×"
