"""Differential semantics tests: PopPy execution must match standard Python
execution (results, observable effect order, ≡_A traces) across the
supported fragment."""

import pytest

from repro.core import (
    ExternalCallError,
    PoppyUnboundLocalError,
    poppy,
    unordered,
)

from helpers_core import ExternalWorld, assert_same

W = ExternalWorld(latency=0.002)
emit, store, compute, slow, peek = W.emit, W.store, W.compute, W.slow, W.peek


# ---------------------------------------------------------------------------
# plain data / control flow


@poppy
def arith(a, b):
    x = a + b * 2
    y = x // 3
    z = x % (b + 1)
    return (x, y, z, x ** 2, -x, x > y, x == y, not (x < y))


def test_arithmetic():
    assert_same(arith, 7, 5)
    assert_same(arith, -3, 2)


@poppy
def strings(name):
    s = f"hello {name}!"
    t = s.upper()
    parts = t.split()
    return (s, t, parts, len(s), s[1:4], s[::-1], "lo" in s)


def test_strings():
    assert_same(strings, "world")


@poppy
def containers():
    t = (1, 2, 3)
    l = [4, 5]
    l.append(6)
    d = {"a": 1, "b": 2}
    d["c"] = 3
    s = {10, 20}
    s.add(30)
    fs = frozenset({1, 2})
    return (t + (4,), l, sorted(d.items()), sorted(s), sorted(fs | {7}),
            t[1], l[-1], d["c"])


def test_containers():
    assert_same(containers)


@poppy
def branching(n):
    if n > 10:
        kind = "big"
    elif n > 5:
        kind = "medium"
    else:
        kind = "small"
    val = 100 if n % 2 == 0 else 200
    both = n > 0 and n < 100
    either = n < 0 or n > 3
    return (kind, val, both, either)


def test_branching():
    for n in (2, 7, 15, -1):
        assert_same(branching, n)


@poppy
def loops(n):
    total = 0
    for i in range(n):
        total += i
    evens = tuple()
    for i in range(n):
        if i % 2 == 0:
            evens += (i,)
    i = 0
    squares = []
    while i * i < n:
        squares.append(i * i)
        i += 1
    return (total, evens, squares)


def test_loops():
    assert_same(loops, 9)
    assert_same(loops, 0)


@poppy
def nested_loops(m, n):
    grid = []
    for i in range(m):
        row = tuple()
        for j in range(n):
            if (i + j) % 2 == 0:
                row += (i * j,)
        grid.append(row)
    return grid


def test_nested_loops():
    assert_same(nested_loops, 3, 4)


@poppy
def unpacking(pairs):
    total = 0
    names = tuple()
    for name, v in pairs:
        total += v
        names += (name,)
    a, b = ("x", "y")
    (c, d), e = (("p", "q"), "r")
    return (total, names, a, b, c, d, e)


def test_unpacking():
    assert_same(unpacking, (("u", 1), ("v", 2), ("w", 3)))


@poppy
def comprehensions(n):
    sq = [i * i for i in range(n)]
    ev = [i for i in range(n) if i % 2 == 0]
    st = {i % 3 for i in range(n)}
    dc = {i: i * 2 for i in range(n) if i > 1}
    pairs = [(i, j) for i in range(3) for j in range(2)]
    return (sq, ev, sorted(st), sorted(dc.items()), pairs)


def test_comprehensions():
    assert_same(comprehensions, 6)


@poppy
def chained_compare(a, b, c):
    return (a < b < c, a <= b <= c, a < b > c, 0 < a < 10 < b)


def test_chained_compare():
    assert_same(chained_compare, 1, 2, 3)
    assert_same(chained_compare, 2, 2, 1)


# ---------------------------------------------------------------------------
# functions, closures, recursion


@poppy
def helper_sum(xs):
    t = 0
    for x in xs:
        t += x
    return t


@poppy
def calls_helper(xs):
    a = helper_sum(xs)
    b = helper_sum((a, a))
    return a + b


def test_internal_calls():
    assert_same(calls_helper, (1, 2, 3))


@poppy
def with_defaults(a, b=10, c=20):
    return a + b + c


def test_defaults_and_kwargs():
    assert_same(with_defaults, 1)
    assert_same(with_defaults, 1, c=5)
    assert_same(with_defaults, 1, 2, 3)


@poppy
def nested_def(scale):
    def mul(x):
        return x * scale

    def twice(f, x):
        return f(f(x))

    return (mul(3), twice(mul, 2))


def test_nested_def_closure():
    assert_same(nested_def, 5)


@poppy
def lambda_sort(pairs):
    return sorted(pairs, key=lambda p: p[1])


def test_lambda_passed_to_external():
    assert_same(lambda_sort, (("a", 3), ("b", 1), ("c", 2)))


@poppy
def fib(n):
    if n < 2:
        out = n
    else:
        out = fib(n - 1) + fib(n - 2)
    return out


def test_recursion():
    assert_same(fib, 10)


@poppy
def while_loop_external(n):
    x = 0
    r = compute(n)
    while x < 3:
        emit(f"iter {x} {r}")
        x += 1
    return x


def test_while_with_external():
    assert_same(while_loop_external, 4, world=W)


# ---------------------------------------------------------------------------
# externals: results and effects


@poppy
def tot_like(task, n):
    cache = frozenset()
    values = tuple()
    for idx, state in enumerate(("a", "a", "b", "b", "c")[:n]):
        if state in cache:
            v = "dup"
            emit(f"{idx}: duplicate")
        else:
            v = compute(f"{task}/{state}")
            cache |= {state}
            emit(f"{idx}: new")
        values += (v,)
    return values


def test_tot_like_pattern():
    r, diag = assert_same(tot_like, "t", 5, world=W)
    assert r == ("c(t/a)", "dup", "c(t/b)", "dup", "c(t/c)")


@poppy
def mutation_order(xs):
    acc = []
    for x in xs:
        y = compute(x)
        acc.append(y)
        emit(len(acc))
    return acc


def test_list_mutation_order():
    assert_same(mutation_order, ("p", "q", "r"), world=W)


@poppy
def readonly_vs_store():
    store(1)
    a = peek()
    b = peek()
    store(2)
    c = peek()
    return (a, b, c)


def test_readonly_window():
    r, _ = assert_same(readonly_vs_store, world=W)
    assert r == (1, 1, 2)


class Box:
    pass


@poppy
def obj_fields():
    obj = Box()
    obj.x = 5
    obj.y = obj.x + 1
    obj.x += 10
    return (obj.x, obj.y)


def test_object_mutation():
    r, _ = assert_same(obj_fields, world=W)
    assert r == (15, 6)


@poppy
def aug_everything():
    d = {}
    l = [1, 2]
    d["k"] = 1
    d["k"] += 5
    l[0] += 100
    return (d["k"], l[0])


def test_aug_subscript():
    r1, _ = assert_same(aug_everything)
    assert r1 == (6, 101)


# ---------------------------------------------------------------------------
# errors


def test_unbound_local():
    @poppy
    def bad(flag):
        if flag:
            x = 1
        return x  # unbound when flag is False

    assert bad(True) == 1
    with pytest.raises(PoppyUnboundLocalError):
        bad(False)


def test_external_exception_surfaces():
    @unordered
    def boom(x):
        raise ValueError(f"boom {x}")

    @poppy
    def calls_boom():
        a = boom(1)
        return a

    with pytest.raises(ExternalCallError):
        calls_boom()


def test_fragment_fallback():
    # break is unsupported → falls back to sequential external execution
    with pytest.warns(UserWarning, match="outside the supported fragment"):
        @poppy
        def has_break(n):
            t = 0
            for i in range(n):
                if i == 3:
                    break
                t += i
            return t

        assert has_break(10) == 3  # still runs correctly (plain Python)
    assert not has_break.compiles


def test_strict_mode_raises():
    from repro.core import PoppyCompileError

    with pytest.raises(PoppyCompileError):
        @poppy(strict=True)
        def has_raise():
            raise ValueError("x")

        has_raise.lfunc  # trigger compile


# ---------------------------------------------------------------------------
# misc semantics


@poppy
def truthiness(xs):
    n = 0
    if xs:
        n += 1
    if len(xs) > 2:
        n += 10
    return n


def test_truthiness():
    assert_same(truthiness, ())
    assert_same(truthiness, (1, 2, 3))


@poppy
def global_const():
    return GLOBAL_VALUE * 2


GLOBAL_VALUE = 21


def test_global_resolution():
    assert_same(global_const)


@poppy
def shadowing(x):
    y = x
    for x in range(3):
        y += x
    return (x, y)


def test_loop_var_shadowing():
    assert_same(shadowing, 100)


@poppy
def dict_set_literals(a, b):
    d = {a: b, "fixed": 1}
    s = {a, b, a}
    return (sorted(d.items(), key=str), sorted(s, key=str))


def test_dict_set_literals():
    assert_same(dict_set_literals, "k", "v")


@poppy
def star_slices(xs):
    return (xs[1:], xs[:2], xs[::2], xs[1:4:2])


def test_slices():
    assert_same(star_slices, tuple(range(8)))
