"""Extended PopPy coverage: agent loops (LLM-driven while), classification
tables, freshness semantics, dynamic classifiers, deeper fragment corners."""

import asyncio


from repro.core import (
    external,
    poppy,
    unordered,
)
from repro.core.registry import (
    READONLY,
    SEQUENTIAL,
    UNORDERED,
    get_callable_class,
)

from helpers_core import ExternalWorld, assert_same

W = ExternalWorld(latency=0.002)
emit, compute = W.emit, W.compute


# ---------------------------------------------------------------------------
# agent-in-a-loop: while loop whose condition depends on LLM results


@unordered
async def llm_step(state):
    await asyncio.sleep(0.003)
    return state + 1


@poppy
def agent_loop(start, limit):
    state = start
    steps = 0
    while state < limit:
        state = llm_step(state)
        steps += 1
        emit(f"step {steps}")
    return (state, steps)


def test_agent_while_loop():
    r, _ = assert_same(agent_loop, 0, 5, world=W)
    assert r == (5, 5)


@poppy
def react_style(task, max_iters):
    history = tuple()
    done = False
    i = 0
    while i < max_iters and not done:
        thought = llm_step(i * 10)
        history += (thought,)
        if thought > 25:
            done = True
        i += 1
    return (history, done)


def test_react_style_loop():
    assert_same(react_style, "t", 5, world=W)


# ---------------------------------------------------------------------------
# classification tables


def test_operator_classification():
    assert get_callable_class(None.__class__ or None, (), {}, ()) or True
    from repro.core import stdlib as sl
    # immutable args → unordered
    assert get_callable_class(sl.py_add, (1, 2), {}, ()) == UNORDERED
    assert get_callable_class(sl.py_add, ("a", "b"), {}, ()) == UNORDERED
    # mutable arg → readonly
    assert get_callable_class(sl.py_add, ([1], [2]), {}, ()) == READONLY
    # in-place on mutable lhs → sequential
    assert get_callable_class(sl.py_iadd, ([1], [2]), {}, ()) == SEQUENTIAL
    # in-place on tuple → unordered (the paper's += example)
    assert get_callable_class(sl.py_iadd, ((1,), (2,)), {}, ()) == UNORDERED
    # in-place with mutable rhs → readonly
    assert get_callable_class(sl.py_iadd, ((1,), [2]), {}, ()) == READONLY
    # freshness upgrade: fresh set literal with immutable elements
    assert get_callable_class(sl.py_ior, (frozenset(), {"x"}), {},
                              (False, True)) == UNORDERED
    # ...but not when elements are mutable
    assert get_callable_class(sl.py_ior, (frozenset(), {(1,), }), {},
                              (False, True)) == UNORDERED
    assert get_callable_class(sl.py_contains, ([["m"]], "x"), {},
                              (True,)) == READONLY


def test_method_classification():
    lst = [1, 2]
    assert get_callable_class(lst.append, (3,), {}, ()) == SEQUENTIAL
    assert get_callable_class(lst.count, (1,), {}, ()) == READONLY
    d = {"a": 1}
    assert get_callable_class(d.update, ({},), {}, ()) == SEQUENTIAL
    assert get_callable_class(d.get, ("a",), {}, ()) == READONLY
    s = {1}
    assert get_callable_class(s.add, (2,), {}, ()) == SEQUENTIAL
    # immutable receiver methods
    assert get_callable_class("ab".upper, (), {}, ()) == UNORDERED
    assert get_callable_class((1, 2).count, (1,), {}, ()) == UNORDERED
    assert get_callable_class("x".join, (["a"],), {}, ()) == READONLY


def test_builtin_classification():
    assert get_callable_class(print, ("x",), {}, ()) == SEQUENTIAL
    assert get_callable_class(len, ((1, 2),), {}, ()) == UNORDERED
    assert get_callable_class(len, ([1, 2],), {}, ()) == READONLY
    assert get_callable_class(sorted, ((3, 1),), {}, ()) == UNORDERED
    # unannotated function → sequential (paper default)
    def plain(x):
        return x
    assert get_callable_class(plain, (1,), {}, ()) == SEQUENTIAL


def test_custom_dynamic_classifier():
    calls = []

    @external(classify=lambda args, kwargs, fresh:
              UNORDERED if args and args[0] > 0 else SEQUENTIAL)
    def maybe_ordered(x):
        calls.append(x)
        return x * 2

    @poppy
    def prog():
        a = maybe_ordered(5)     # unordered
        b = maybe_ordered(-1)    # sequential
        return (a, b)

    assert prog() == (10, -2)


# ---------------------------------------------------------------------------
# fragment corners


@poppy
def nested_parallel(tasks):
    results = tuple()
    for t in tasks:
        r = sub_fanout(t)
        results += (r,)
    return results


@poppy
def sub_fanout(t):
    a = compute(f"{t}/a")
    b = compute(f"{t}/b")
    return (a, b)


def test_nested_function_parallelism():
    import time
    W.reset()
    t0 = time.perf_counter()
    out = nested_parallel(("x", "y", "z"))
    dt = time.perf_counter() - t0
    assert len(out) == 3
    # 6 calls at 2 ms: parallel ≈ one latency, sequential ≈ 12 ms
    assert W.max_in_flight >= 3


@poppy
def kwargs_everywhere(a, *, scale=2, bias=0):
    return a * scale + bias


def test_kwonly_args():
    assert_same(kwargs_everywhere, 5)
    assert_same(kwargs_everywhere, 5, scale=3, bias=1)


@poppy
def mixed_containers():
    d = {"xs": [1, 2], "t": (3, 4)}
    d["xs"].append(5)
    out = []
    for k in sorted(d):
        v = d[k]
        out.append((k, len(v)))
    return out


def test_mixed_containers():
    assert_same(mixed_containers)


@poppy
def string_building(items):
    parts = tuple()
    for i, x in enumerate(items):
        parts += (f"{i}={x!r:>6s}",)
    return " | ".join(parts)


def test_fstring_conversions():
    assert_same(string_building, ("a", "bb"))


@poppy
def walrus(x):
    y = (z := x + 1) * 2
    return (y, z)


def test_walrus():
    assert_same(walrus, 5)


@poppy
def generator_expr(xs):
    return sum(x * x for x in xs)


def test_genexp_eager():
    assert_same(generator_expr, (1, 2, 3))


def test_int8_kv_cache_model():
    """int8 KV cache: decode within quantization tolerance of forward."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen3-14b").reduced().replace(kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                              cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :8]},
                                  capacity=12)
    pos = jnp.full((2,), 8, jnp.int32)
    l2, cache = model.decode_step(params, cache, toks[:, 8:9], pos)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(full[:, 8]),
                               rtol=0.1, atol=0.1)


def test_pydantic_frozen_classification():
    """Paper §6.1: frozen Pydantic BaseModels count as core immutables."""
    import pydantic

    class FrozenDoc(pydantic.BaseModel):
        model_config = pydantic.ConfigDict(frozen=True)
        text: str

    class MutableDoc(pydantic.BaseModel):
        text: str

    from repro.core.registry import is_immutable
    assert is_immutable(FrozenDoc(text="x"))
    assert not is_immutable(MutableDoc(text="x"))

    from repro.core import stdlib as sl
    assert get_callable_class(sl.py_eq, (FrozenDoc(text="a"),
                                         FrozenDoc(text="a")), {}, ()) \
        == UNORDERED
    assert get_callable_class(sl.py_eq, (MutableDoc(text="a"), 1), {}, ()) \
        == READONLY


def test_register_immutable_type():
    from repro.core import register_immutable_type
    from repro.core import stdlib as sl

    class Point:
        def __init__(self, x):
            self.x = x

    assert get_callable_class(sl.py_eq, (Point(1), Point(1)), {}, ()) \
        == READONLY
    register_immutable_type(Point)
    assert get_callable_class(sl.py_eq, (Point(1), Point(1)), {}, ()) \
        == UNORDERED
