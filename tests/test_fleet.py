"""Replica-fleet tests: the prefix-affinity routing policy (warm routing,
cold fallback, saturation spill, broken digests), ``make_router``
validation errors, the per-replica route/prefix-hit counters, and the
``EngineFleet`` end-to-end token-exactness invariant — a routed fleet
returns exactly the tokens a single engine returns."""

import asyncio

import jax
import pytest

from repro.configs import get_config
from repro.dispatch import Dispatcher, PrefixAffinityRouter, make_router
from repro.dispatch.stats import DispatchStats
from repro.models import build_model
from repro.serving import ByteTokenizer, EngineFleet, ServingEngine


class WarmBackend:
    """Stub backend with a programmable prefix digest."""

    def __init__(self, depths=None):
        self.depths = depths or {}

    def prefix_probe(self, hint):
        return self.depths.get(hint, 0)


class BrokenDigestBackend:
    def prefix_probe(self, hint):
        raise RuntimeError("digest exploded")


# -- prefix-affinity policy ---------------------------------------------------


def test_affinity_routes_to_warmest_replica():
    cold, warm, warmer = (WarmBackend(), WarmBackend({"s1": 8}),
                          WarmBackend({"s1": 32}))
    r = make_router([cold, warm, warmer], policy="prefix_affinity")
    assert r.pick("s1").backend is warmer
    # warmth beats load (no spill configured): even with backlog the
    # warm replica keeps its session
    r.pick("s1").begin()
    assert r.pick("s1").backend is warmer


def test_affinity_cold_falls_back_to_least_outstanding():
    backends = [WarmBackend(), WarmBackend()]
    r = make_router(backends, policy="prefix_affinity")
    first = r.pick("never-seen")
    first.begin()
    assert r.pick("never-seen").backend is not first.backend
    # no hint at all (e.g. an embed call) also falls back
    assert isinstance(r, PrefixAffinityRouter)
    assert r.pick(None) is not None


def test_affinity_min_match_threshold():
    shallow = WarmBackend({"s1": 4})
    idle = WarmBackend()
    r = make_router([shallow, idle], policy="prefix_affinity",
                    min_match=8)
    shallow_rep = r.replicas[0]
    shallow_rep.begin()     # shallow is warmer but busier…
    picked = r.pick("s1")   # …and 4 < min_match → least-outstanding
    assert picked.backend is idle


def test_affinity_overload_spill():
    warm = WarmBackend({"s1": 16})
    cold = WarmBackend()
    r = make_router([warm, cold], policy="prefix_affinity",
                    overload_slack=1)
    warm_rep = r.replicas[0]
    # within slack: backlog 1 vs 0 → still routes warm
    warm_rep.begin()
    assert r.pick("s1").backend is warm
    # beyond slack: backlog 2 vs 0 → re-paying prefill beats queueing
    warm_rep.begin()
    assert r.pick("s1").backend is cold


def test_affinity_tie_breaks_by_load_then_wrr():
    a, b = WarmBackend({"s1": 16}), WarmBackend({"s1": 16})
    r = make_router([a, b], policy="prefix_affinity")
    r.replicas[0].begin()
    assert r.pick("s1").backend is b        # equally warm, b is idler
    r.replicas[0].end()
    picks = {r.pick("s1").backend for _ in range(2)}
    assert picks == {a, b}                  # equal warmth+load interleaves


def test_affinity_broken_digest_never_fails_routing():
    r = make_router([BrokenDigestBackend(), WarmBackend({"s1": 8})],
                    policy="prefix_affinity")
    assert r.pick("s1").backend is r.replicas[1].backend
    # both broken/cold → plain least-outstanding, still no exception
    r2 = make_router([BrokenDigestBackend(), BrokenDigestBackend()],
                     policy="prefix_affinity")
    assert r2.pick("s1") is not None


# -- make_router validation ---------------------------------------------------


@pytest.mark.parametrize("policy", ["weighted", "least_outstanding",
                                    "prefix_affinity"])
def test_make_router_rejects_weight_length_mismatch(policy):
    with pytest.raises(ValueError, match="len\\(weights\\) must match"):
        make_router(["a", "b", "c"], policy=policy, weights=[1, 2])


@pytest.mark.parametrize("policy", ["weighted", "least_outstanding",
                                    "prefix_affinity"])
@pytest.mark.parametrize("bad", [[1, 0], [1, -2.5]])
def test_make_router_rejects_nonpositive_weights(policy, bad):
    with pytest.raises(ValueError, match="weights must be positive"):
        make_router(["a", "b"], policy=policy, weights=bad)


def test_make_router_rejects_name_length_mismatch():
    with pytest.raises(ValueError, match="len\\(names\\) must match"):
        make_router(["a", "b"], names=["only-one"])


def test_make_router_rejects_unknown_policy_kwargs():
    with pytest.raises(TypeError):
        make_router(["a"], policy="weighted", min_match=2)


# -- per-replica route counters ----------------------------------------------


def test_note_route_counters_and_snapshot():
    st = DispatchStats()
    st.note_route("r0", matched=12)     # warm routed request
    st.note_route("r0", matched=0)      # probed, cold
    st.note_route("r0", matched=None)   # un-probe-able (no hint)
    snap = st.snapshot()["backends"]["r0"]
    assert snap["routed"] == 3
    assert snap["prefix_probed"] == 2
    assert snap["prefix_hits"] == 1
    assert snap["prefix_hit_tokens"] == 12
    assert "affinity 1/2 warm (12 tok)" in st.report()


def test_dispatcher_records_per_replica_routes():
    class CountingBackend(WarmBackend):
        async def generate(self, prompt, *, max_tokens, temperature,
                           stop):
            return f"out:{prompt}"

    warm = CountingBackend({"s1:q": 6})
    cold = CountingBackend()
    d = Dispatcher([warm, cold], policy="prefix_affinity",
                   names=["warm", "cold"])

    async def go():
        return await d.generate("s1:q", max_tokens=4, temperature=0.0,
                                stop=None)

    assert asyncio.run(go()) == "out:s1:q"
    snap = d.stats.snapshot()["backends"]
    assert snap["warm"]["routed"] == 1
    assert snap["warm"]["prefix_hits"] == 1
    assert snap["warm"]["prefix_hit_tokens"] == 6
    assert snap.get("cold", {}).get("routed", 0) == 0


# -- EngineFleet end-to-end ---------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("stablelm-3b").reduced().replace(
        num_layers=1, d_model=64, num_heads=4, head_dim=16, d_ff=128)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(3))


def test_fleet_validation(tiny):
    model, params = tiny
    with pytest.raises(ValueError, match="replicas"):
        EngineFleet(model, params, replicas=0)
    with pytest.raises(ValueError, match="tp"):
        EngineFleet(model, params, tp=0)
    with pytest.raises(RuntimeError, match="devices"):
        EngineFleet(model, params, tp=1 + len(jax.devices()))


def test_fleet_tokens_match_single_engine(tiny):
    model, params = tiny
    tok = ByteTokenizer(model.cfg.vocab_size)
    prompts = [f"session {i % 2}: question {i}" for i in range(6)]

    single = ServingEngine(model, params, max_slots=4, max_len=64)

    async def ref():
        outs = await asyncio.gather(*(
            single.generate(tok.encode(p), max_new_tokens=6,
                            temperature=0.0) for p in prompts))
        await single.stop()
        return [tok.decode(o) for o in outs]

    fleet = EngineFleet(model, params, replicas=2, max_slots=4,
                        max_len=64)

    async def routed():
        outs = await asyncio.gather(*(
            fleet.dispatcher.generate(p, max_tokens=6, temperature=0.0,
                                      stop=None) for p in prompts))
        await fleet.stop()
        return list(outs)

    expected = asyncio.run(ref())
    got = asyncio.run(routed())
    assert got == expected
    # the fleet actually spread load and counted it per replica
    snap = fleet.stats.snapshot()["backends"]
    assert sum(b["routed"] for b in snap.values()) == len(prompts)
    assert all(b["routed"] > 0 for b in snap.values())
    assert fleet.engine_stats().keys() == {"replica0", "replica1"}
