"""Effect-domain-keyed sequence variables (DESIGN.md §2.2).

Covers the keyed ordering state (KeyedSeqState fork/join), the
``effects=`` annotation surface (static keys, per-call templates,
callables), per-domain lock-protocol behavior (independent sequential
chains overlap; ``"*"`` joins everything; per-domain program order is
preserved), the per-domain ≡_A checker, the freshness/object-identity
classification of mutating intrinsics, and the session-keyed MemoryStore.
"""

import asyncio


from helpers_core import ExternalWorld, assert_same
from repro.core import (
    equivalent,
    poppy,
    readonly,
    recording,
    sequential,
    sequential_mode,
    unordered,
)
from repro.core import registry
from repro.core.registry import force_sequential_annotations
from repro.core.trace import Trace
from repro.core.values import KS_READY, S_READY, KeyedSeqState, SeqState


# ---------------------------------------------------------------------------
# a keyed world: per-session ordered externals with latency + observability


class KeyedWorld:
    def __init__(self, latency=0.02):
        self.latency = latency
        self.reset()
        world = self

        @sequential(effects=("mem:{session}",), returns_immutable=True)
        async def write(session, text):
            world.in_flight += 1
            world.max_in_flight = max(world.max_in_flight, world.in_flight)
            await asyncio.sleep(world.latency)
            world.in_flight -= 1
            world.log.append((session, text))
            world.cells[session] = text
            return f"{session}:{text}"

        @readonly(effects=("mem:{session}",), returns_immutable=True)
        async def read(session):
            await asyncio.sleep(world.latency / 2)
            world.log.append((session, "<read>"))
            return world.cells.get(session, "")

        @sequential
        async def global_sync(tag):
            world.in_flight += 1
            world.max_in_flight = max(world.max_in_flight, world.in_flight)
            await asyncio.sleep(world.latency)
            world.in_flight -= 1
            world.log.append(("*", tag))
            return tag

        self.write = write
        self.read = read
        self.global_sync = global_sync

    def reset(self):
        self.log = []
        self.cells = {}
        self.in_flight = 0
        self.max_in_flight = 0

    def session_log(self, session):
        return [t for s, t in self.log if s == session]


W = KeyedWorld()


# ---------------------------------------------------------------------------
# KeyedSeqState unit behavior


def _state():
    loop = asyncio.new_event_loop()
    try:
        return SeqState(loop.create_future(), loop.create_future()), loop
    finally:
        pass


def test_keyed_state_fallback_and_join():
    assert KS_READY.state_for("anything") is S_READY
    loop = asyncio.new_event_loop()
    try:
        a = SeqState(loop.create_future(), loop.create_future())
        root = SeqState(loop.create_future(), loop.create_future())
        ks = KeyedSeqState({"*": root, "mem:a": a})
        assert ks.state_for("mem:a") is a
        assert ks.state_for("mem:b") is root  # falls back to the root
        joined = ks.join(("*",))
        assert set(map(id, joined)) == {id(a), id(root)}
        assert ks.join(("mem:a", "mem:a")) == [a]
    finally:
        loop.close()


def test_keyed_fork_star_collapses_and_keyed_updates():
    loop = asyncio.new_event_loop()
    try:
        mk = lambda: SeqState(loop.create_future(), loop.create_future())
        ks0 = KS_READY
        ks1, links1 = ks0.fork(("mem:a",), mk)
        assert set(ks1.domains) == {"mem:a"}
        assert len(links1) == 1 and links1[0][0] is S_READY
        ks2, links2 = ks1.fork(("*",), mk)
        # the "*" fork touches the root and the live domain
        assert set(ks2.domains) == {"*", "mem:a"}
        assert len(links2) == 2
        # a later key falls back to the new root
        assert ks2.state_for("mem:b") is ks2.domains["*"]
    finally:
        loop.close()


def test_keyed_fork_prunes_resolved_domains():
    loop = asyncio.new_event_loop()
    try:
        mk = lambda: SeqState(loop.create_future(), loop.create_future())
        ks = KeyedSeqState({"mem:a": S_READY, "mem:b": S_READY})
        ks2, _ = ks.fork(("mem:c",), mk)
        # resolved side entries (root also resolved) are dropped
        assert set(ks2.domains) == {"mem:c"}
    finally:
        loop.close()


# ---------------------------------------------------------------------------
# end-to-end: independent sequential chains overlap, order preserved


@poppy
def two_chains(n):
    r = ()
    for i in range(n):
        a = W.write("a", f"a{i}")
        b = W.write("b", f"b{i}")
        r += (a, b)
    return r


def test_disjoint_sequential_domains_overlap():
    W.reset()
    with recording() as t1, sequential_mode():
        r1 = two_chains(3)
    W.reset()
    with recording() as t2:
        r2 = two_chains(3)
    assert r1 == r2
    ok, why = equivalent(t1, t2)
    assert ok, why
    # under PopPy the two chains ran concurrently...
    assert W.max_in_flight >= 2
    # ...while each session's writes stayed in program order
    assert W.session_log("a") == ["a0", "a1", "a2"]
    assert W.session_log("b") == ["b0", "b1", "b2"]


@poppy
def chain_with_global(n):
    r = ()
    for i in range(n):
        r += (W.write("a", f"a{i}"), W.write("b", f"b{i}"))
    g = W.global_sync("barrier")
    r += (W.write("a", "post"), W.write("b", "post"), g)
    return r


def test_star_call_joins_all_domains():
    W.reset()
    with recording() as t1, sequential_mode():
        r1 = chain_with_global(2)
    W.reset()
    with recording() as t2:
        r2 = chain_with_global(2)
    assert r1 == r2
    ok, why = equivalent(t1, t2)
    assert ok, why
    # the unkeyed sequential call is a barrier: it runs after every keyed
    # write before it, and the post-barrier writes run after it
    log = W.log
    bar = log.index(("*", "barrier"))
    pre = [e for e in log[:bar] if e[1] != "<read>"]
    post = [e for e in log[bar + 1:]]
    assert {t for _, t in pre} == {"a0", "a1", "b0", "b1"}
    assert {t for _, t in post} == {"post"}


@poppy
def readers_and_writers():
    w1 = W.write("a", "v1")
    r1 = W.read("a")
    w2 = W.write("a", "v2")
    r2 = W.read("a")
    rb = W.read("b")
    return (w1, r1, w2, r2, rb)


def test_readonly_keyed_windows():
    W.reset()
    assert_same(readers_and_writers)


def test_force_sequential_collapses_domains():
    W.reset()
    with recording() as t_plain, sequential_mode():
        r1 = two_chains(3)
    W.reset()
    W.max_in_flight = 0
    with force_sequential_annotations(), recording():
        r2 = two_chains(3)
    assert r1 == r2
    assert W.max_in_flight == 1  # Fig. 7 mode: zero extracted parallelism


# ---------------------------------------------------------------------------
# effects declaration surface: templates, callables, degradation


def test_effect_keys_template_and_params():
    @sequential(effects=("mem:{session}", "audit"))
    def f(session, text):
        return None

    info = f.__poppy_external__
    assert registry.effect_keys(info, ["s1", "x"], {}) == ("mem:s1", "audit")
    assert registry.effect_keys(info, [], {"session": "s2", "text": "x"}) \
        == ("mem:s2", "audit")
    # a missing field cannot resolve → None (engine degrades locking)
    assert registry.effect_keys(info, [], {}) is None


def test_effect_keys_callable_and_failure_degrades():
    @sequential(effects=lambda a, k: (f"dom:{a[0]}",))
    def f(x):
        return None

    info = f.__poppy_external__
    assert registry.effect_keys(info, [7], {}) == ("dom:7",)

    @sequential(effects=lambda a, k: a[5])  # raises IndexError
    def g(x):
        return None

    assert registry.effect_keys(g.__poppy_external__, [1], {}) == ("*",)


class _EffWorld:
    def __init__(self):
        self.log = []
        world = self

        @sequential(effects=lambda a, k: (f"k:{a[0] % 2}",),
                    returns_immutable=True)
        async def kw(x):
            await asyncio.sleep(0.005)
            world.log.append(x)
            return x

        self.kw = kw


EFF = _EffWorld()


@poppy
def callable_keyed(n):
    r = ()
    for i in range(n):
        r += (EFF.kw(i),)
    return r


def test_callable_effects_differential():
    EFF.log.clear()
    with recording() as t1, sequential_mode():
        r1 = callable_keyed(6)
    EFF.log.clear()
    with recording() as t2:
        r2 = callable_keyed(6)
    assert r1 == r2
    ok, why = equivalent(t1, t2)
    assert ok, why
    # per-parity order preserved
    assert [x for x in EFF.log if x % 2 == 0] == [0, 2, 4]
    assert [x for x in EFF.log if x % 2 == 1] == [1, 3, 5]


@poppy
def pending_key_arg():
    # the *session* argument of the second write is itself a pending
    # external result → locking degrades to "*", which only over-orders;
    # results and per-domain traces must still match plain Python
    s = W.write("a", "seed")
    r = W.write(s, "x")
    return (s, r)


def test_pending_key_argument_degrades_soundly():
    W.reset()
    assert_same(pending_key_arg)


# ---------------------------------------------------------------------------
# per-domain ≡_A checker


def _mk_trace(events):
    tr = Trace()
    for name, cls, effects in events:
        tr.record_direct(name, cls, args_repr="()", effects=effects)
    return tr


def test_equivalent_per_domain_allows_cross_domain_reorder():
    a = _mk_trace([("w", "sequential", ("d:a",)),
                   ("w", "sequential", ("d:b",))])
    b = _mk_trace([("w", "sequential", ("d:b",)),
                   ("w", "sequential", ("d:a",))])
    ok, why = equivalent(a, b)
    assert ok, why


def test_equivalent_per_domain_rejects_in_domain_reorder():
    a = _mk_trace([("w1", "sequential", ("d:a",)),
                   ("w2", "sequential", ("d:a",))])
    b = _mk_trace([("w2", "sequential", ("d:a",)),
                   ("w1", "sequential", ("d:a",))])
    ok, why = equivalent(a, b)
    assert not ok
    assert "d:a" in why


def test_equivalent_star_orders_against_every_domain():
    a = _mk_trace([("w", "sequential", ("d:a",)),
                   ("g", "sequential", ("*",))])
    b = _mk_trace([("g", "sequential", ("*",)),
                   ("w", "sequential", ("d:a",))])
    ok, why = equivalent(a, b)
    assert not ok


def test_equivalent_readonly_windows_per_domain():
    a = _mk_trace([("r", "readonly", ("d:a",)),
                   ("w", "sequential", ("d:a",))])
    b = _mk_trace([("w", "sequential", ("d:a",)),
                   ("r", "readonly", ("d:a",))])
    ok, _ = equivalent(a, b)
    assert not ok  # readonly crossed a sequential point of its domain


def test_equivalent_backwards_compatible_default_domain():
    a = _mk_trace([("x", "sequential", ("*",)), ("u", "unordered", ("*",))])
    b = _mk_trace([("u", "unordered", ("*",)), ("x", "sequential", ("*",))])
    ok, why = equivalent(a, b)
    assert ok, why


# ---------------------------------------------------------------------------
# mutating-intrinsic classification (satellite: freshness + object domains)


def test_classify_write_mirrors_classify_inplace():
    cw = registry.classify_write
    d = {}
    # mutable, non-fresh target → sequential
    assert cw([d, "k", 1], {}, ()) == registry.SEQUENTIAL
    # fresh target with immutable contents → upgraded like classify_inplace
    assert cw([{}, "k", 1], {}, (True,)) == registry.UNORDERED
    assert cw([{}, "k", []], {}, (True,)) == registry.READONLY


def test_mutating_intrinsics_are_object_keyed():
    eff = registry._effects_obj([{"x": 1}, "x", 2], {})
    assert len(eff) == 1 and eff[0].startswith("obj:")
    # unknown mutable targets stay on the global domain (custom
    # __setitem__ may run arbitrary code)
    class C:
        pass

    assert registry._effects_obj([C(), "x", 2], {}) == ("*",)


def test_attr_intrinsics_object_keyed_only_for_plain_instances():
    class Plain:
        pass

    class Propped:
        @property
        def x(self):
            return 1

    eff = registry._effects_obj_attr([Plain(), "x", 2], {})
    assert eff[0].startswith("obj:")
    assert registry._effects_obj_attr([Propped(), "x", 2], {}) == ("*",)


def test_receiver_only_methods_object_keyed():
    lst = [1]
    assert registry.dynamic_effect_keys(lst.append)[0].startswith("obj:")
    # content-reading / callable-taking methods stay global
    assert registry.dynamic_effect_keys(lst.sort) == ("*",)
    assert registry.dynamic_effect_keys(len) == ("*",)


SLOW = ExternalWorld(latency=0.03)


@poppy
def dict_build_with_externals():
    d = {}
    d["a"] = SLOW.compute("a")
    d["b"] = SLOW.compute("b")
    SLOW.emit("e1")
    SLOW.emit("e2")
    return (d["a"], d["b"])


def test_local_dict_build_does_not_serialize_unrelated_externals():
    """Regression (satellite): py_setitem on a local dict is keyed to the
    dict's identity domain, so the unrelated @sequential emits no longer
    wait for the dict writes (which wait for the slow computes)."""
    import time

    SLOW.reset()
    with recording() as t_plain, sequential_mode():
        r1 = dict_build_with_externals()
    SLOW.reset()
    t0 = time.perf_counter()
    with recording() as t_poppy:
        r2 = dict_build_with_externals()
    dt = time.perf_counter() - t0
    assert r1 == r2
    ok, why = equivalent(t_plain, t_poppy)
    assert ok, why
    assert SLOW.out == [("emit", "e1"), ("emit", "e2")]
    # plain time ≈ 2·compute + 2·emit-ish; keyed-poppy overlaps the
    # computes with each other; the dict writes wait on the computes but
    # the emits don't wait on the dict writes
    assert dt < 3.5 * SLOW.latency, dt


@poppy
def dict_read_after_write():
    d = {}
    d["a"] = SLOW.compute("x")
    v = d["a"]
    d["a"] = "overwritten"
    return (v, d["a"])


def test_object_domain_preserves_read_write_order():
    SLOW.reset()
    assert_same(dict_read_after_write)


@poppy
def list_method_chain():
    acc = []
    acc.append(SLOW.compute("1"))
    acc.append(SLOW.compute("2"))
    SLOW.emit("between")
    acc.append("3")
    return tuple(acc)


def test_list_methods_object_keyed_differential():
    SLOW.reset()
    assert_same(list_method_chain)


# ---------------------------------------------------------------------------
# MemoryStore


from repro.core.ai import MemoryStore, SimulatedBackend, llm, use_backend

MEM = MemoryStore("m")


@poppy
def memory_sessions(n):
    outs = ()
    for k in range(n):
        a = llm(f"think {k}", max_tokens=8)
        MEM.append(f"s{k}", a)
        MEM.append(f"s{k}", "done")
        outs += (MEM.read(f"s{k}"),)
    return outs


def test_memory_store_differential_and_parallel():
    be = SimulatedBackend(base_s=0.03)
    with use_backend(be):
        MEM.clear()
        with recording() as t1, sequential_mode():
            r1 = memory_sessions(3)
        snap1 = MEM.snapshot()
        MEM.clear()
        with recording() as t2:
            r2 = memory_sessions(3)
    assert r1 == r2
    assert snap1 == MEM.snapshot()
    ok, why = equivalent(t1, t2)
    assert ok, why
    assert be.max_in_flight >= 2  # llm calls overlapped across sessions
    doms = t2.domain_summary()
    assert doms.get("m:s0") == 3  # two appends + one read


def test_memory_store_namespaces_are_independent():
    m1, m2 = MemoryStore("n1"), MemoryStore("n2")
    info1 = m1.append.__poppy_external__
    assert registry.effect_keys(info1, ["sess", "x"], {}) == ("n1:sess",)
    info2 = m2.append.__poppy_external__
    assert registry.effect_keys(info2, ["sess", "x"], {}) == ("n2:sess",)


# ---------------------------------------------------------------------------
# returns_immutable hint


def test_returns_immutable_seeds_static_classification():
    @unordered(returns_immutable=True)
    async def gen(x):
        return f"g{x}"

    @poppy
    def chain():
        acc = ()
        for i in range(3):
            g = gen(f"p{i}")
            acc += (f"<{g}>",)  # f-string over a pending hinted result
        return acc

    assert_same(chain)


def test_operator_result_hint_not_trusted_for_mutable_operands():
    """Regression: ``list + list`` returns a *mutable* list even though the
    operator intrinsic declares imm_result (valid only for immutable
    operands).  The downstream truth-test must stay ordered against the
    pending mutation."""

    @sequential(returns_immutable=False)
    async def make_list():
        await asyncio.sleep(0.01)
        return []

    @poppy
    def truth_after_mutation():
        x = make_list()
        y = x + []
        y.append(1)
        out = "falsy"
        if y:
            out = "truthy"
        return out

    assert_same(truth_after_mutation)


def test_empty_effects_tuple_normalizes_to_star():
    @sequential(effects=())
    def f(x):
        return None

    assert registry.effect_keys(f.__poppy_external__, [1], {}) == ("*",)

    log = []

    @sequential(effects=(), returns_immutable=True)
    async def write(x):
        await asyncio.sleep((5 - x) / 200.0)
        log.append(x)
        return x

    @poppy
    def two_writes():
        a = write(1)
        b = write(2)
        return (a, b)

    with recording() as t1, sequential_mode():
        r1 = two_writes()
    plain_log, log[:] = list(log), []
    with recording() as t2:
        r2 = two_writes()
    assert r1 == r2
    assert plain_log == log == [1, 2]  # program order preserved
    ok, why = equivalent(t1, t2)
    assert ok, why


def test_http_effects_keyword_url():
    from repro.core.ai import _http_effects

    assert _http_effects([], {"url": "https://h.example/x"}) \
        == ("http:h.example",)
    assert _http_effects(["https://h.example/x"], {}) == ("http:h.example",)


def test_dispatch_stats_per_domain():
    from repro.dispatch import DispatchStats

    st = DispatchStats()
    st.note_domains(("http:a", "http:b"))
    st.note_domains(("http:a",))
    assert st.per_domain == {"http:a": 2, "http:b": 1}
    assert st.snapshot()["per_domain"] == {"http:a": 2, "http:b": 1}
