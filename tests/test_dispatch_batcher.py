"""Dispatch-layer micro-batcher tests (DESIGN.md §5, §2.3): windowing of
concurrent singles, batched pipeline composition with the per-element cache
(hits skip the batch, identical misses coalesce), one admission unit per
batch, per-element error isolation, the gather fallback for backends
without list payloads, and the per-batch stats surface."""

from __future__ import annotations

import asyncio

from repro.core.ai import SimulatedBackend, use_backend
from repro.dispatch import (
    AdmissionPolicy,
    BatchPolicy,
    Dispatcher,
    make_batch_policy,
)


def run(coro):
    return asyncio.run(coro)


def test_make_batch_policy_forms():
    assert make_batch_policy(None) is None
    assert make_batch_policy(True).max_batch == 32
    p = make_batch_policy({"max_batch": 4, "max_wait_s": 0.1})
    assert (p.max_batch, p.max_wait_s) == (4, 0.1)
    q = BatchPolicy(max_batch=2)
    assert make_batch_policy(q) is q


def test_concurrent_singles_window_into_one_batch():
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher([be], batch=BatchPolicy(max_batch=8, max_wait_s=0.05))

    async def go():
        return await asyncio.gather(*(
            d.generate(f"p{i}", max_tokens=4, temperature=0.0, stop=None)
            for i in range(5)))

    outs = run(go())
    assert outs == [be.response(f"p{i}", 4) for i in range(5)]
    # the partial window flushed by timer as one batched request
    assert be.batches == [5], be.batches
    snap = d.batch_stats.snapshot()
    assert snap["batches"] == 1 and snap["elements"] == 5
    assert snap["size_hist"] == {5: 1}
    assert 0 < snap["fill_ratio"] == 5 / 8
    assert "batches: 1 carrying 5 elements" in d.stats.report()


def test_full_window_flushes_without_timer():
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher([be], batch=BatchPolicy(max_batch=3, max_wait_s=10.0))

    async def go():
        return await asyncio.gather(*(
            d.embed(f"t{i}") for i in range(6)))

    outs = run(asyncio.wait_for(go(), timeout=5.0))
    assert len(outs) == 6
    assert be.batches == [3, 3], be.batches


def test_generate_batch_one_admission_unit():
    """A batch traverses admission as one request: max_concurrency=1 admits
    the whole batch at once instead of trickling elements."""
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher([be], admission=AdmissionPolicy(max_concurrency=1))

    async def go():
        return await d.generate_batch(
            [f"p{i}" for i in range(8)], max_tokens=4, temperature=0.0,
            stop=None)

    outs = run(go())
    assert outs == [be.response(f"p{i}", 4) for i in range(8)]
    assert be.batches == [8]
    assert d.stats.dispatched == 1
    assert be.max_in_flight == 8   # all elements processed concurrently


def test_batch_pipeline_cache_hits_and_coalescing():
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher([be], cache=True)

    async def go():
        first = await d.embed_batch(["a", "b"])
        second = await d.embed_batch(["a", "c", "c", "d"])
        return first, second

    first, second = run(go())
    assert second[0] == first[0]            # "a" from cache
    assert second[1] == second[2]           # duplicate "c" coalesced
    assert be.batches == [2, 2]             # second batch carried c, d only
    assert d.stats.cache_hits == 1
    assert d.stats.coalesced == 1
    assert d.stats.cache_misses == 4        # a, b, c, d


def test_per_element_error_isolation_and_no_error_caching():
    class FlakyBackend(SimulatedBackend):
        async def generate_batch(self, prompts, *, max_tokens, temperature,
                                 stop):
            return [RuntimeError(f"boom {p}") if p.startswith("bad")
                    else self.response(p, max_tokens) for p in prompts]

    be = FlakyBackend(time_scale=0.01)
    d = Dispatcher([be], cache=True)

    async def go():
        r1 = await d.generate_batch(["ok1", "bad1", "ok2"], max_tokens=4,
                                    temperature=0.0, stop=None)
        # failed elements are not cached or left stuck in-flight
        r2 = await d.generate_batch(["bad1", "ok1"], max_tokens=4,
                                    temperature=0.0, stop=None)
        return r1, r2

    r1, r2 = run(go())
    assert r1[0] == be.response("ok1", 4)
    assert isinstance(r1[1], RuntimeError)
    assert r1[2] == be.response("ok2", 4)
    assert isinstance(r2[0], RuntimeError)   # re-dispatched, failed again
    assert r2[1] == be.response("ok1", 4)    # served from cache
    assert d.stats.cache_hits == 1
    assert not d.cache.inflight


def test_duck_typed_backend_gather_fallback():
    """A backend without list-payload methods still works: the batch fans
    out per element inside one routed/admitted request, with per-element
    isolation via return_exceptions."""

    class Bare:   # deliberately not a Backend subclass
        def __init__(self):
            self.calls = []

        async def generate(self, prompt, *, max_tokens, temperature, stop):
            self.calls.append(prompt)
            if prompt == "bad":
                raise ValueError("nope")
            return f"g:{prompt}"

        async def embed(self, text):
            self.calls.append(text)
            return (1.0,)

    be = Bare()
    d = Dispatcher([be])

    async def go():
        return await d.generate_batch(["x", "bad", "y"], max_tokens=4,
                                      temperature=0.0, stop=None)

    outs = run(go())
    assert outs[0] == "g:x" and outs[2] == "g:y"
    assert isinstance(outs[1], ValueError)
    assert sorted(be.calls) == ["bad", "x", "y"]
    assert d.stats.dispatched == 1


def test_singles_and_batches_share_cache_keys():
    """An element cached by a batched request answers a later single call
    (and vice versa) — the per-element keys are identical."""
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher([be], cache=True)

    async def go():
        await d.generate_batch(["p"], max_tokens=4, temperature=0.0,
                               stop=None)
        return await d.generate("p", max_tokens=4, temperature=0.0,
                                stop=None)

    out = run(go())
    assert out == be.response("p", 4)
    assert d.stats.cache_hits == 1
    assert len(be.calls) == 1


def test_ambient_trivial_dispatcher_batches():
    """The trivial (no-argument) dispatcher resolves the ambient backend
    per call and still carries batched requests — the engine's windows
    work with zero dispatcher configuration."""
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher()

    async def go():
        with use_backend(be):
            return await d.embed_batch(["u", "v"])

    outs = run(go())
    assert outs == [be._embedding("u"), be._embedding("v")]
    assert be.batches == [2]


def test_list_valued_stop_bypasses_windowing():
    """Regression: an unhashable request option (a list-valued ``stop``)
    cannot key a micro-batch window — such calls must dispatch unbatched
    instead of crashing on the window-dict lookup."""
    be = SimulatedBackend(time_scale=0.01)
    d = Dispatcher([be], batch=BatchPolicy(max_batch=8, max_wait_s=0.01))

    async def go():
        single = await d.generate("p", max_tokens=4, temperature=0.0,
                                  stop=["END"])
        burst = await d.generate_batch(["q", "r"], max_tokens=4,
                                       temperature=0.0, stop=["END"])
        return single, burst

    single, burst = run(go())
    assert single == be.response("p", 4)
    assert burst == [be.response("q", 4), be.response("r", 4)]
    # the burst still went out as one batched backend request
    assert be.batches == [2], be.batches
