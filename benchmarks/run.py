"""Benchmark harness entry point — one benchmark per paper table/figure:

    Table 1  program characteristics       table1_characteristics
    Fig. 5   PopPy vs Python speedups      fig5_speedup (async + sync clients)
    Fig. 10  blocking-external offload     fig10_sync_offload
    Fig. 11  effect-domain keying          fig11_effect_domains
    Fig. 6   ToT execution trace           fig6_trace
    Fig. 7   interpreter overhead          fig7_overhead
    Fig. 8   parallelism scaling           fig8_scaling
    §Roofline  per-(arch×shape) terms      roofline (subprocess, 512 devs)

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke     # CI equivalence job

Results land in experiments/apps/ and experiments/roofline/.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import time


def smoke():
    """Benchmark smoke job (CI): run fig5/fig9/fig10/fig11 with tiny
    parameters.  Every one of these figures asserts result equality (and,
    for fig5/fig11, ≡_A trace equivalence) against sequential-mode Python
    on every trial — so an equivalence regression fails this job in
    minutes instead of surfacing in a full benchmark run.  Speedup
    acceptance bars are *not* enforced here (tiny N is timing noise);
    correctness is."""
    from benchmarks import (fig5_speedup, fig9_dispatch, fig10_sync_offload,
                            fig11_effect_domains)

    t0 = time.time()
    print("== smoke: fig5 (equality + ≡_A per trial) ==", flush=True)
    fig5_speedup.run(trials=1, scale=0.1, camel_count=2)
    print("\n== smoke: fig9 (dispatch preserves sequential semantics) ==",
          flush=True)
    fig9_dispatch.run(trials=1, scale=0.3)
    print("\n== smoke: fig10 (offload result equality) ==", flush=True)
    fig10_sync_offload.run(trials=1, delay=0.05, sweep=(2, 4), smoke=True)
    print("\n== smoke: fig11 (per-domain equality + ≡_A per trial) ==",
          flush=True)
    fig11_effect_domains.run(trials=1, scale=0.1, sweep=(2, 4), n_steps=3,
                             smoke=True)
    print(f"\nbenchmark smoke passed in {time.time() - t0:.0f}s")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer trials / smaller sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-N equivalence smoke (fig5/9/10/11); "
                         "used by CI")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the 512-device roofline subprocess")
    ap.add_argument("--roofline-arch", action="append", default=None)
    args = ap.parse_args()

    if args.smoke:
        return smoke()

    trials = 2 if args.quick else 3
    t0 = time.time()

    from benchmarks import (fig5_speedup, fig6_trace, fig7_overhead,
                            fig8_scaling, fig10_sync_offload,
                            fig11_effect_domains, table1_characteristics)

    print("=" * 72)
    print("Table 1 — benchmark program characteristics")
    print("=" * 72)
    table1_characteristics.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 5 — median speedup of PopPy over standard Python")
    print("=" * 72)
    fig5_speedup.run(trials=trials,
                     camel_count=6 if args.quick else 30)

    print("\n" + "=" * 72)
    print("Fig. 5 (sync clients) — same apps, blocking SDK externals")
    print("=" * 72)
    fig5_speedup.run(trials=trials, camel_count=6 if args.quick else 30,
                     sync_externals=True)

    print("\n" + "=" * 72)
    print("Fig. 10 — executor offload: overlap of blocking externals")
    print("=" * 72)
    fig10_sync_offload.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 11 — effect-domain keying: independent sequential chains")
    print("=" * 72)
    if args.quick:
        fig11_effect_domains.run(trials=trials, sweep=(2, 4))
    else:
        fig11_effect_domains.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 6 — ToT execution trace (queue → dispatch → resolve)")
    print("=" * 72)
    fig6_trace.run()

    print("\n" + "=" * 72)
    print("Fig. 7 — interpreter overhead (all externals forced sequential)")
    print("=" * 72)
    fig7_overhead.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 8 — speedup vs available parallelism")
    print("=" * 72)
    if args.quick:
        fig8_scaling.run(trials=1, beams=(1, 5, 10), assessments=(1, 5, 10))
    else:
        fig8_scaling.run(trials=trials)

    if not args.skip_roofline:
        print("\n" + "=" * 72)
        print("§Roofline — per-(arch × shape) terms from the compiled "
              "dry-run (512-device subprocess)")
        print("=" * 72)
        sys.stdout.flush()  # keep tee ordering across the subprocess
        cmd = [sys.executable, "-m", "benchmarks.roofline"]
        for a in (args.roofline_arch or []):
            cmd += ["--arch", a]
        if args.quick:
            for a in ("qwen3-14b", "olmoe-1b-7b", "mamba2-2.7b"):
                cmd += ["--arch", a]
        r = subprocess.run(cmd)
        if r.returncode != 0:
            print("roofline subprocess failed", file=sys.stderr)
            return 1

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
