"""Benchmark harness entry point — one benchmark per paper table/figure:

    Table 1  program characteristics       table1_characteristics
    Fig. 5   PopPy vs Python speedups      fig5_speedup (async + sync clients)
    Fig. 10  blocking-external offload     fig10_sync_offload
    Fig. 11  effect-domain keying          fig11_effect_domains
    Fig. 12  auto-batching                 fig12_autobatch
    Fig. 13  prefix-aware prefill          fig13_prefix_prefill
    Fig. 16  speculative execution         fig16_speculation
    Fig. 17  durability / chaos            fig17_durability
    Fig. 6   ToT execution trace           fig6_trace
    Fig. 7   interpreter overhead          fig7_overhead
    Fig. 8   parallelism scaling           fig8_scaling
    §Roofline  per-(arch×shape) terms      roofline (subprocess, 512 devs)

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke     # CI equivalence job

Results land in experiments/apps/ and experiments/roofline/; ``--smoke``
additionally writes the machine-readable ``BENCH_smoke.json`` consumed by
the ``bench-gate`` CI job (benchmarks/perf_gate.py).
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

#: Where --smoke writes its machine-readable result summary.
SMOKE_JSON = "experiments/ci/BENCH_smoke.json"


def smoke(out_path=SMOKE_JSON):
    """Benchmark smoke job (CI): run fig5/fig9/fig10/fig11/fig12/fig13
    with tiny parameters.  Every one of these figures asserts result
    equality (and, for fig5/fig11/fig12/fig13, ≡_A trace equivalence)
    against sequential-mode Python on every trial — so an equivalence
    regression fails this job in minutes instead of surfacing in a full
    benchmark run.  Speedup
    acceptance bars are *not* enforced here (tiny N is timing noise);
    correctness is — but every figure's speedups are recorded in
    ``BENCH_smoke.json`` (per-figure ``equivalent`` boolean + ``speedups``
    map) so the ``bench-gate`` CI job can track the trajectory against
    ``benchmarks/baseline.json``."""
    from benchmarks import (fig5_speedup, fig9_dispatch, fig10_sync_offload,
                            fig11_effect_domains, fig12_autobatch,
                            fig13_prefix_prefill, fig14_paged_kv,
                            fig15_fleet, fig16_speculation, fig17_durability,
                            obs_overhead)

    t0 = time.time()
    figures = {}

    def attempt(name, title, fn, extract):
        print(f"== smoke: {name} ({title}) ==", flush=True)
        try:
            r = fn()
            figures[name] = {"equivalent": True, "speedups": extract(r)}
        except AssertionError as e:
            figures[name] = {"equivalent": False, "error": str(e),
                             "speedups": {}}
            print(f"EQUIVALENCE FAILURE [{name}]: {e}", flush=True)
        print(flush=True)

    # the smoke fig5 run is span-traced end to end; the resulting
    # Perfetto trace is uploaded as a CI artifact (debugging a CI-only
    # perf regression starts from this file)
    attempt("fig5", "equality + ≡_A per trial",
            lambda: fig5_speedup.run(trials=1, scale=0.1, camel_count=2,
                                     trace_out="experiments/ci/"
                                               "smoke_trace.json"),
            lambda r: {"geomean": r[1]["geomean"]})
    attempt("fig9", "dispatch preserves sequential semantics",
            lambda: fig9_dispatch.run(trials=1, scale=0.3),
            lambda r: {"routed": r["speedup_routed"],
                       "warm": r["speedup_warm"]})
    attempt("fig10", "offload result equality",
            lambda: fig10_sync_offload.run(trials=1, delay=0.05,
                                           sweep=(2, 4), smoke=True),
            lambda rows: {"offload_n4": next(
                x["speedup"] for x in rows if x["n"] == 4)})
    attempt("fig11", "per-domain equality + ≡_A per trial",
            lambda: fig11_effect_domains.run(trials=1, scale=0.1,
                                             sweep=(2, 4), n_steps=3,
                                             smoke=True),
            lambda rows: {"keyed_vs_single_k4": next(
                x["speedup_vs_single"] for x in rows
                if x["k_agents"] == 4)})
    attempt("fig12", "batched equality + ≡_A per trial",
            lambda: fig12_autobatch.run(trials=1, n_docs=8, scale=0.3,
                                        smoke=True),
            lambda r: {"batched_vs_unbatched":
                       r["speedup_batched_vs_unbatched"],
                       "batched_vs_plain": r["speedup_batched_vs_plain"]})
    # fig13 additionally asserts the prefill jit-compilation bound every
    # run; jit_headroom (= bound / compilations) is tracked by the gate so
    # a bucketing regression (recompile-per-length) fails CI even when
    # the hard bound still holds at smoke scale
    attempt("fig13", "token equality + ≡_A + prefill-compilation bound",
            lambda: fig13_prefix_prefill.run(trials=1, n=8,
                                             prefix_chars=400, smoke=True),
            lambda r: {"prefix_vs_nocache":
                       r["speedup_prefix_vs_nocache"],
                       "jit_headroom": r["jit_headroom"]})
    # fig14 asserts token-exactness + ≡_A + zero-copy admission + both
    # compile bounds every trial; admitted_users_ratio is a capacity
    # count (not a timing), so the gate tracks it even at smoke scale,
    # and jit_headroom guards against recompile-per-length on the paged
    # prefill path
    attempt("fig14", "paged-KV token equality + ≡_A + zero-copy + "
                     "compile bounds",
            lambda: fig14_paged_kv.run(trials=1, smoke=True),
            lambda r: {"admitted_users_ratio": r["admitted_users_ratio"],
                       "jit_headroom": r["jit_headroom"]})
    # fig15 asserts token-exactness + ≡_A of every fleet run vs the
    # single-replica fleet and the sequential oracle, the strict
    # affinity > least-outstanding warm-route rate gap (read from the
    # per-replica dispatch counters), per-replica compile bounds, and the
    # ≥2.5× 4-vs-1-replica drain bar — the scaling ratio counts overlapped
    # simulated device steps, so it holds at smoke scale; the TP leg runs
    # whenever ≥2 devices are visible (the multi-device CI job sets
    # XLA_FLAGS=--xla_force_host_platform_device_count=8)
    attempt("fig15", "fleet token equality + ≡_A + affinity > "
                     "least-outstanding + ≥2.5× scale-out",
            lambda: fig15_fleet.run(trials=1, smoke=True),
            lambda r: {"fleet_scaling_x4": r["fleet_scaling_x4"],
                       "affinity_hit_rate": r["affinity_hit_rate"]})
    # fig16 asserts, on every trial, result equality + ≡_A of both the
    # non-speculative and speculative runs against the sequential oracle,
    # zero committed effects from losing arms, the bounded wasted-work
    # ratio, perfect predictor validation, and race-loser drain through
    # the dispatcher — so a speculation-soundness regression (a loser
    # effect committing, a leaked admission, an unvalidated guess
    # escaping) fails this job even at smoke scale; the ≥2× speedup bar
    # is enforced only in full runs, but spec_vs_nonspec is tracked by
    # the gate
    attempt("fig16", "speculative equality + ≡_A + zero loser effects + "
                     "bounded waste + race drain",
            lambda: fig16_speculation.run(trials=1, call_s=0.01,
                                          smoke=True),
            lambda r: {"spec_vs_nonspec":
                       r["branchy"]["speedup_spec_vs_nonspec"],
                       "race": r["race"]["speedup_race"]})
    # fig17 is the chaos leg: a subprocess is hard-killed (os._exit) mid-
    # journal and resumed — asserting byte-identical results + ≡_A vs the
    # uninterrupted run and a ≥80% journal-replay fraction (the gated
    # recovery_replay_fraction metric, baseline 1.0 with the gate's 0.2
    # tolerance = the ISSUE's 0.8 floor); plus seeded dispatcher fault
    # injection with zero leaked admissions and the breaker's full
    # open → probe → close cycle, and injected serving-backend failures
    # leaving decode slots / KV pages / prefix pins exactly balanced
    attempt("fig17", "kill/resume byte-identical + ≡_A + ≥80% replay + "
                     "zero leaks under injected faults",
            lambda: fig17_durability.run(trials=1, smoke=True),
            lambda r: {"recovery_replay_fraction":
                       r["recovery"]["recovery_replay_fraction"]})
    # obs_overhead asserts the tracing-enabled overhead bar (<5% pairwise
    # delta on fig5 tiny-N) and critical-path attribution soundness; an
    # assertion failure surfaces through the same equivalence machinery
    attempt("obs_overhead", "tracing <5% overhead + attribution ≥85%",
            lambda: obs_overhead.run(),
            lambda r: {"disabled_vs_enabled": r["disabled_vs_enabled"]})

    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    payload = {"figures": figures,
               "elapsed_s": round(time.time() - t0, 1)}
    out.write_text(json.dumps(payload, indent=1))
    print(f"wrote {out}")
    failed = [n for n, f in figures.items() if not f["equivalent"]]
    if failed:
        print(f"benchmark smoke FAILED (equivalence): {', '.join(failed)}")
        return 1
    print(f"benchmark smoke passed in {time.time() - t0:.0f}s")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer trials / smaller sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-N equivalence smoke (fig5/9/10/11); "
                         "used by CI")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the 512-device roofline subprocess")
    ap.add_argument("--roofline-arch", action="append", default=None)
    args = ap.parse_args()

    if args.smoke:
        return smoke()

    trials = 2 if args.quick else 3
    t0 = time.time()

    from benchmarks import (fig5_speedup, fig6_trace, fig7_overhead,
                            fig8_scaling, fig10_sync_offload,
                            fig11_effect_domains, fig12_autobatch,
                            fig13_prefix_prefill, fig14_paged_kv,
                            fig15_fleet, fig16_speculation,
                            fig17_durability, table1_characteristics)

    print("=" * 72)
    print("Table 1 — benchmark program characteristics")
    print("=" * 72)
    table1_characteristics.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 5 — median speedup of PopPy over standard Python")
    print("=" * 72)
    fig5_speedup.run(trials=trials,
                     camel_count=6 if args.quick else 30)

    print("\n" + "=" * 72)
    print("Fig. 5 (sync clients) — same apps, blocking SDK externals")
    print("=" * 72)
    fig5_speedup.run(trials=trials, camel_count=6 if args.quick else 30,
                     sync_externals=True)

    print("\n" + "=" * 72)
    print("Fig. 10 — executor offload: overlap of blocking externals")
    print("=" * 72)
    fig10_sync_offload.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 11 — effect-domain keying: independent sequential chains")
    print("=" * 72)
    if args.quick:
        fig11_effect_domains.run(trials=trials, sweep=(2, 4))
    else:
        fig11_effect_domains.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 12 — auto-batching of pending unordered externals")
    print("=" * 72)
    fig12_autobatch.run(trials=trials,
                        n_docs=8 if args.quick else 32)

    print("\n" + "=" * 72)
    print("Fig. 13 — prefix-aware KV reuse + bucketed chunked prefill")
    print("=" * 72)
    fig13_prefix_prefill.run(trials=trials,
                             n=8 if args.quick else 16)

    print("\n" + "=" * 72)
    print("Fig. 14 — paged KV: admitted users at fixed memory, zero-copy "
          "prefix sharing")
    print("=" * 72)
    fig14_paged_kv.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 15 — replica fleet: routed scale-out + prefix-affinity "
          "placement")
    print("=" * 72)
    fig15_fleet.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 16 — speculation: branchy routing cascade, predicted "
          "routes, racing rollouts")
    print("=" * 72)
    fig16_speculation.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 17 — durability: kill/resume recovery, fault injection, "
          "breaker")
    print("=" * 72)
    fig17_durability.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 6 — ToT execution trace (queue → dispatch → resolve)")
    print("=" * 72)
    fig6_trace.run()

    print("\n" + "=" * 72)
    print("Fig. 7 — interpreter overhead (all externals forced sequential)")
    print("=" * 72)
    fig7_overhead.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 8 — speedup vs available parallelism")
    print("=" * 72)
    if args.quick:
        fig8_scaling.run(trials=1, beams=(1, 5, 10), assessments=(1, 5, 10))
    else:
        fig8_scaling.run(trials=trials)

    if not args.skip_roofline:
        print("\n" + "=" * 72)
        print("§Roofline — per-(arch × shape) terms from the compiled "
              "dry-run (512-device subprocess)")
        print("=" * 72)
        sys.stdout.flush()  # keep tee ordering across the subprocess
        cmd = [sys.executable, "-m", "benchmarks.roofline"]
        for a in (args.roofline_arch or []):
            cmd += ["--arch", a]
        if args.quick:
            for a in ("qwen3-14b", "olmoe-1b-7b", "mamba2-2.7b"):
                cmd += ["--arch", a]
        r = subprocess.run(cmd)
        if r.returncode != 0:
            print("roofline subprocess failed", file=sys.stderr)
            return 1

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
