"""Benchmark harness entry point — one benchmark per paper table/figure:

    Table 1  program characteristics       table1_characteristics
    Fig. 5   PopPy vs Python speedups      fig5_speedup (async + sync clients)
    Fig. 10  blocking-external offload     fig10_sync_offload
    Fig. 6   ToT execution trace           fig6_trace
    Fig. 7   interpreter overhead          fig7_overhead
    Fig. 8   parallelism scaling           fig8_scaling
    §Roofline  per-(arch×shape) terms      roofline (subprocess, 512 devs)

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-roofline]

Results land in experiments/apps/ and experiments/roofline/.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer trials / smaller sweeps")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the 512-device roofline subprocess")
    ap.add_argument("--roofline-arch", action="append", default=None)
    args = ap.parse_args()

    trials = 2 if args.quick else 3
    t0 = time.time()

    from benchmarks import (fig5_speedup, fig6_trace, fig7_overhead,
                            fig8_scaling, fig10_sync_offload,
                            table1_characteristics)

    print("=" * 72)
    print("Table 1 — benchmark program characteristics")
    print("=" * 72)
    table1_characteristics.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 5 — median speedup of PopPy over standard Python")
    print("=" * 72)
    fig5_speedup.run(trials=trials,
                     camel_count=6 if args.quick else 30)

    print("\n" + "=" * 72)
    print("Fig. 5 (sync clients) — same apps, blocking SDK externals")
    print("=" * 72)
    fig5_speedup.run(trials=trials, camel_count=6 if args.quick else 30,
                     sync_externals=True)

    print("\n" + "=" * 72)
    print("Fig. 10 — executor offload: overlap of blocking externals")
    print("=" * 72)
    fig10_sync_offload.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 6 — ToT execution trace (queue → dispatch → resolve)")
    print("=" * 72)
    fig6_trace.run()

    print("\n" + "=" * 72)
    print("Fig. 7 — interpreter overhead (all externals forced sequential)")
    print("=" * 72)
    fig7_overhead.run(trials=trials)

    print("\n" + "=" * 72)
    print("Fig. 8 — speedup vs available parallelism")
    print("=" * 72)
    if args.quick:
        fig8_scaling.run(trials=1, beams=(1, 5, 10), assessments=(1, 5, 10))
    else:
        fig8_scaling.run(trials=trials)

    if not args.skip_roofline:
        print("\n" + "=" * 72)
        print("§Roofline — per-(arch × shape) terms from the compiled "
              "dry-run (512-device subprocess)")
        print("=" * 72)
        sys.stdout.flush()  # keep tee ordering across the subprocess
        cmd = [sys.executable, "-m", "benchmarks.roofline"]
        for a in (args.roofline_arch or []):
            cmd += ["--arch", a]
        if args.quick:
            for a in ("qwen3-14b", "olmoe-1b-7b", "mamba2-2.7b"):
                cmd += ["--arch", a]
        r = subprocess.run(cmd)
        if r.returncode != 0:
            print("roofline subprocess failed", file=sys.stderr)
            return 1

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
