"""CI perf gate: compare a ``--smoke`` run against the checked-in baseline.

``benchmarks/run.py --smoke`` writes ``experiments/ci/BENCH_smoke.json``
with a per-figure ``equivalent`` boolean and ``speedups`` map.  This gate
fails (exit 1) when

* any figure's ``equivalent`` is false (a semantics regression — the
  figure's per-trial result-equality / ≡_A assertion fired), or
* any speedup metric listed in ``benchmarks/baseline.json`` regressed by
  more than ``tolerance`` (default 20%) below its baseline value, or
* a figure/metric the baseline tracks is missing from the current run
  (the pipeline silently lost coverage).

Refreshing the baseline (after an intentional perf change)::

    PYTHONPATH=src python -m benchmarks.run --smoke
    python benchmarks/perf_gate.py --refresh
    git add benchmarks/baseline.json   # commit with the change

``--refresh`` records the measured speedups verbatim.  Smoke-scale timings
are noisy, so after refreshing on a quiet machine it is fine (encouraged)
to hand-floor individual values further down — the gate only checks a
lower bound, and a conservative floor still catches real regressions
while staying quiet on loaded CI runners.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_CURRENT = "experiments/ci/BENCH_smoke.json"
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"
DEFAULT_TOLERANCE = 0.2


def compare(current: dict, baseline: dict,
            tolerance: float = DEFAULT_TOLERANCE) -> list[str]:
    """Return the list of gate failures (empty = pass)."""
    failures = []
    cur_figs = current.get("figures", {})
    base_figs = baseline.get("figures", {})
    for name, fig in sorted(cur_figs.items()):
        if not fig.get("equivalent", False):
            detail = fig.get("error", "")
            failures.append(
                f"{name}: equivalence FAILED"
                + (f" — {detail}" if detail else ""))
    for name, base in sorted(base_figs.items()):
        cur = cur_figs.get(name)
        if cur is None:
            failures.append(f"{name}: tracked by baseline but missing "
                            f"from the current run")
            continue
        cur_speedups = cur.get("speedups", {})
        for metric, base_v in sorted(base.get("speedups", {}).items()):
            cur_v = cur_speedups.get(metric)
            if cur_v is None:
                failures.append(f"{name}.{metric}: tracked by baseline "
                                f"but missing from the current run")
                continue
            floor = base_v * (1.0 - tolerance)
            if cur_v < floor:
                failures.append(
                    f"{name}.{metric}: speedup {cur_v:.2f}× is more than "
                    f"{tolerance:.0%} below baseline {base_v:.2f}× "
                    f"(floor {floor:.2f}×)")
    return failures


def refresh(current: dict, baseline_path) -> None:
    payload = {
        "_comment": (
            "Speedup floors for the CI bench-gate, from "
            "`benchmarks/run.py --smoke` via `perf_gate.py --refresh`. "
            "Values may be hand-floored below measurements; the gate "
            "fails when a metric drops more than `tolerance` below its "
            "entry. See benchmarks/perf_gate.py for the refresh recipe."),
        "tolerance": DEFAULT_TOLERANCE,
        "figures": {
            name: {"speedups": {m: round(v, 3)
                                for m, v in fig.get("speedups", {}).items()}}
            for name, fig in sorted(current.get("figures", {}).items())
        },
    }
    Path(baseline_path).write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {baseline_path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="BENCH_smoke.json from `benchmarks.run --smoke`")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional regression (default: the "
                         "baseline file's `tolerance`, else 0.2)")
    ap.add_argument("--refresh", action="store_true",
                    help="rewrite the baseline from the current run")
    args = ap.parse_args(argv)

    try:
        current = json.loads(Path(args.current).read_text())
    except (OSError, ValueError) as e:
        print(f"perf-gate: cannot read current results "
              f"{args.current!r}: {e}", file=sys.stderr)
        return 1
    if args.refresh:
        refresh(current, args.baseline)
        return 0
    try:
        baseline = json.loads(Path(args.baseline).read_text())
    except (OSError, ValueError) as e:
        print(f"perf-gate: cannot read baseline {args.baseline!r}: {e}",
              file=sys.stderr)
        return 1
    tol = args.tolerance if args.tolerance is not None \
        else baseline.get("tolerance", DEFAULT_TOLERANCE)
    failures = compare(current, baseline, tolerance=tol)
    if failures:
        print(f"perf-gate FAILED ({len(failures)} problem"
              f"{'s' if len(failures) != 1 else ''}):")
        for f in failures:
            print(f"  ✗ {f}")
        return 1
    n = sum(len(f.get("speedups", {}))
            for f in baseline.get("figures", {}).values())
    print(f"perf-gate passed: all figures equivalent, {n} speedup "
          f"metric{'s' if n != 1 else ''} within {tol:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
