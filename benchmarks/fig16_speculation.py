"""Fig. 16: speculative execution (beyond-paper; DESIGN.md §2.4,
EXPERIMENTS.md §Fig. 16).

Three legs, all differential against the non-speculative engine:

  branchy  a routing cascade: each round classifies twice (coarse →
           fine, both slow @unordered calls whose results feed ``if``
           conditions) before dispatching one of four experts, then
           audits the pick through a @sequential effect.  Non-
           speculatively every round costs 3 serial stages; with
           ``speculation()`` both arms of every branch run while the
           conditions are still pending, so a round costs ~1 stage.
           The acceptance bar is ≥2× end-to-end over the
           non-speculative engine.
  predict  value speculation: a ``predictor=`` hook on the routing
           external publishes a guess, three dependent enrichments
           launch on it, and validation confirms the guess — the
           route → fan-out chain collapses from 2 stages to ~1.
  race     ``first_success`` over three redundant rollouts with loser
           cancellation through the dispatcher, vs running the
           rollouts sequentially until one succeeds.

Every trial asserts result equality across plain / non-speculative /
speculative runs, ≡_A trace equivalence of both engine runs against the
sequential oracle, zero committed effects from losing arms
(``loser_effects`` + audit-log equality), a bounded wasted-work ratio
(speculative dispatches ≤ WASTE_BOUND × non-speculative), and — for the
race — that the winner is exactly the deterministic-latency oracle's
pick and the losers fully drained (no leaked dispatch admissions).

    PYTHONPATH=src:. python benchmarks/fig16_speculation.py
"""

from __future__ import annotations

import asyncio
import hashlib
import json
import statistics
import time
from pathlib import Path

from repro.core import (equivalent, first_success, poppy, recording,
                        sequential, sequential_mode, speculation, unordered)
from repro.core.ai import SimulatedBackend, llm, use_backend, use_dispatcher

from benchmarks.common import maybe_tracing

ROUNDS = 3
CALL_S = 0.03
#: speculative dispatches per non-speculative dispatch: a round's cascade
#: dispatches at most 7 calls (1 coarse + 2 fine + 4 experts) where the
#: non-speculative engine dispatches 3 — anything past 7/3 (+ slack for
#: the audit tail) means speculation is leaking work it should not start
WASTE_BOUND = 3.0

# module-level state: dispatch log + audit log (reset per run); @poppy
# needs module-level externals so branch arms classify statically
CALLS: list = []
EFFECTS: list = []
_DELAY = {"s": CALL_S}


def _digest(text):
    return int.from_bytes(
        hashlib.sha256(str(text).encode()).digest()[:4], "big")


@unordered(returns_immutable=True)
async def classify(stage, text):
    CALLS.append(("classify", stage))
    await asyncio.sleep(_DELAY["s"])
    return _digest(f"{stage}|{text}") % 2 == 0


@unordered(returns_immutable=True)
async def expert(kind, text):
    CALLS.append(("expert", kind))
    await asyncio.sleep(_DELAY["s"])
    return f"{kind}#{_digest(text) % 997}"


@sequential
def audit(entry):
    # the per-round persistence effect: must only ever record the
    # winning arm's pick, in program order
    EFFECTS.append(entry)
    return None


@poppy
def route_pipeline(q, rounds):
    acc = q
    for i in range(rounds):
        coarse = classify(f"coarse{i}", acc)
        if coarse:
            fine = classify(f"fineA{i}", acc)
            if fine:
                r = expert(f"a1-{i}", acc)
            else:
                r = expert(f"a2-{i}", acc)
        else:
            fine = classify(f"fineB{i}", acc)
            if fine:
                r = expert(f"b1-{i}", acc)
            else:
                r = expert(f"b2-{i}", acc)
        audit(r)
        acc = f"{acc}>{r}"
    return acc


def _predict_route(pos, kw):
    # mirrors ``route``'s digest on the peeked argument; a still-pending
    # (or speculative) argument peeks as a Pending and the int() below
    # raises — returning None declines the prediction
    try:
        return f"route-{_digest(pos[0]) % 4}"
    except Exception:
        return None


@unordered(returns_immutable=True, predictor=_predict_route)
async def pick_route(q):
    CALLS.append(("pick_route", q))
    await asyncio.sleep(_DELAY["s"])
    return f"route-{_digest(q) % 4}"


@unordered(returns_immutable=True)
async def consult(route, k):
    CALLS.append(("consult", route, k))
    await asyncio.sleep(_DELAY["s"])
    return f"{route}/{k}"


@poppy
def routed_fanout(q):
    r = pick_route(q)
    a = consult(r, 0)
    b = consult(r, 1)
    c = consult(r, 2)
    return f"{a}|{b}|{c}"


@poppy
def race_rollouts(q):
    return first_success(
        lambda: llm(f"rollout-a {q}", max_tokens=48),
        lambda: llm(f"rollout-b {q}", max_tokens=8),
        lambda: llm(f"rollout-c {q}", max_tokens=24),
    )


def _reset():
    CALLS.clear()
    EFFECTS.clear()


def _timed(fn, *args, plain=False, spec=False):
    _reset()
    ctx = speculation() if spec else _null()
    with ctx as sp:
        with recording() as tr:
            t0 = time.perf_counter()
            if plain:
                with sequential_mode():
                    r = fn(*args)
            else:
                r = fn(*args)
            dt = time.perf_counter() - t0
    stats = sp.stats if spec else None
    return r, dt, tr, list(EFFECTS), len(CALLS), stats


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


def bench_branchy(*, rounds=ROUNDS, trials=3, call_s=CALL_S):
    _DELAY["s"] = call_s
    times = {"plain": [], "nonspec": [], "spec": []}
    waste = 0.0
    for _ in range(trials):
        r0, dt0, t0, fx0, _, _ = _timed(route_pipeline, "q", rounds,
                                        plain=True)
        r1, dt1, t1, fx1, n1, _ = _timed(route_pipeline, "q", rounds)
        r2, dt2, t2, fx2, n2, st = _timed(route_pipeline, "q", rounds,
                                          spec=True)
        times["plain"].append(dt0)
        times["nonspec"].append(dt1)
        times["spec"].append(dt2)
        assert r0 == r1 == r2, f"results diverge: {r0!r}/{r1!r}/{r2!r}"
        for tag, tr in (("nonspec", t1), ("spec", t2)):
            ok, why = equivalent(t0, tr)
            assert ok, f"{tag}: trace not ≡_A: {why}"
        # rollback airtightness: the audit log is identical in content
        # *and order* across all three runs — no loser effect committed
        assert fx0 == fx1 == fx2, f"effects diverge: {fx0}/{fx1}/{fx2}"
        assert st.loser_effects == 0
        assert st.branches_speculated >= rounds
        assert st.arms_aborted >= rounds
        ratio = n2 / n1
        waste = max(waste, ratio)
        assert ratio <= WASTE_BOUND, (
            f"wasted work unbounded: {n2} speculative dispatches vs "
            f"{n1} non-speculative ({ratio:.2f}× > {WASTE_BOUND}×)")
    med = {m: statistics.median(ts) for m, ts in times.items()}
    return {
        "rounds": rounds,
        **{f"{m}_s": t for m, t in med.items()},
        "speedup_spec_vs_nonspec": med["nonspec"] / med["spec"],
        "speedup_spec_vs_plain": med["plain"] / med["spec"],
        "waste_ratio": waste,
    }


def bench_predict(*, trials=3, call_s=CALL_S):
    _DELAY["s"] = call_s
    times = {"nonspec": [], "spec": []}
    for _ in range(trials):
        r0, _, t0, _, _, _ = _timed(routed_fanout, "qq", plain=True)
        r1, dt1, t1, _, _, _ = _timed(routed_fanout, "qq")
        r2, dt2, t2, _, _, st = _timed(routed_fanout, "qq", spec=True)
        times["nonspec"].append(dt1)
        times["spec"].append(dt2)
        assert r0 == r1 == r2
        for tr in (t1, t2):
            ok, why = equivalent(t0, tr)
            assert ok, f"trace not ≡_A: {why}"
        # the predictor mirrors the route digest, so every guess
        # validates and nothing re-runs
        assert st.predictions == 1 and st.pred_hits == 1
        assert st.redo_runs == 0
    med = {m: statistics.median(ts) for m, ts in times.items()}
    return {
        "nonspec_s": med["nonspec"],
        "spec_s": med["spec"],
        "speedup_predict": med["nonspec"] / med["spec"],
    }


def bench_race(*, trials=3):
    from repro.dispatch import Dispatcher

    race_times, seq_times = [], []
    for _ in range(trials):
        be = SimulatedBackend()
        # the deterministic-latency oracle: the winner must be exactly
        # the rollout the backend's latency model finishes first
        cands = [(f"rollout-{k} hello", mt)
                 for k, mt in (("a", 48), ("b", 8), ("c", 24))]

        def lat(p, mt):
            return be.latency(p, min(mt, 1 + be._digest(p) % 7))

        wp, wmt = min(cands, key=lambda c: lat(*c))
        d = Dispatcher()
        with use_backend(be), use_dispatcher(d):
            with sequential_mode():
                expect = llm(wp, max_tokens=wmt)
            # sequential-fallback baseline: try rollouts one by one
            t0 = time.perf_counter()
            with sequential_mode():
                for p, mt in cands:
                    llm(p, max_tokens=mt)
            seq_times.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            out = race_rollouts("hello")
            race_times.append(time.perf_counter() - t0)
        assert out == expect, f"race winner diverges: {out!r} != {expect!r}"
        st = d.stats
        # losers cancelled *through the dispatcher* and fully drained:
        # no admission left queued, no attempt still in flight
        assert st.races == 1 and st.race_losers == 2 and st.cancelled == 2
        assert st.queue_depth == 0
        assert be._in_flight == 0
    race = statistics.median(race_times)
    seq = statistics.median(seq_times)
    return {
        "race_s": race,
        "sequential_s": seq,
        "speedup_race": seq / race,
    }


def run(out_dir="experiments/apps", trials=3, rounds=ROUNDS, call_s=CALL_S,
        smoke=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, rounds, call_s, smoke)


def _run(out_dir, trials, rounds, call_s, smoke):
    br = bench_branchy(rounds=rounds, trials=trials, call_s=call_s)
    print(f"branchy  plain {br['plain_s']:.3f}s  nonspec "
          f"{br['nonspec_s']:.3f}s  spec {br['spec_s']:.3f}s  "
          f"spec/nonspec {br['speedup_spec_vs_nonspec']:.2f}×  "
          f"(waste {br['waste_ratio']:.2f}×)", flush=True)
    pr = bench_predict(trials=trials, call_s=call_s)
    print(f"predict  nonspec {pr['nonspec_s']:.3f}s  spec "
          f"{pr['spec_s']:.3f}s  {pr['speedup_predict']:.2f}×", flush=True)
    rc = bench_race(trials=trials)
    print(f"race     sequential {rc['sequential_s']:.3f}s  race "
          f"{rc['race_s']:.3f}s  {rc['speedup_race']:.2f}×", flush=True)

    if not smoke:
        assert br["speedup_spec_vs_nonspec"] >= 2.0, (
            f"acceptance: speculation must run the branchy routing app ≥2× "
            f"faster than the non-speculative engine, got "
            f"{br['speedup_spec_vs_nonspec']:.2f}×")
        print(f"\nacceptance: {br['speedup_spec_vs_nonspec']:.2f}× ≥ 2× ✓")

    result = {"branchy": br, "predict": pr, "race": rc}
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig16.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--call-s", type=float, default=CALL_S)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, rounds=args.rounds, call_s=args.call_s,
        trace_out=args.trace_out)
