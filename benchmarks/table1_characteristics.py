"""Table 1: benchmark program characteristics — LoC, For, If, Dyn
(dynamically dispatched call sites), Ext (external functions used), Time
(standard Python execution, median)."""

from __future__ import annotations

import ast
import inspect
import json
import statistics
import textwrap
from pathlib import Path

from benchmarks.common import run_once
from repro.core.bezoar import BCall, BConst


def _count_dynamic_callsites(poppy_fn) -> int:
    """Call sites whose reordering class is resolved at runtime (operators,
    methods, subscripts — BCalls to intrinsics with dynamic classifiers)."""
    from repro.core.registry import ExternalInfo

    def walk(stmts):
        n = 0
        consts = {}
        for s in stmts:
            if isinstance(s, BConst):
                consts[s.dst] = s.value
            if isinstance(s, BCall):
                fn = consts.get(s.fn)
                info = getattr(fn, "__poppy_external__", None)
                if isinstance(info, ExternalInfo) and info.classify:
                    n += 1
            for attr in ("then", "orelse", "body", "cond_body"):
                sub = getattr(s, attr, None)
                if isinstance(sub, list):
                    n += walk(sub)
            if hasattr(s, "func"):
                n += walk(s.func.body)
        return n

    return walk(poppy_fn.bezoar.body)


def analyze_app(mod) -> dict:
    loc = n_for = n_if = dyn = 0
    for f in mod.FUNCS:
        src = textwrap.dedent(inspect.getsource(f.original))
        loc += len([l for l in src.splitlines() if l.strip()])
        tree = ast.parse(src)
        for node in ast.walk(tree):
            if isinstance(node, ast.For):
                n_for += 1
            elif isinstance(node, ast.If):
                n_if += 1
        dyn += _count_dynamic_callsites(f)
    return {"LoC": loc, "For": n_for, "If": n_if, "Dyn": dyn,
            "Ext": len(mod.EXTERNALS)}


def run(out_dir="experiments/apps", trials=3, scale=1.0):
    from benchmarks.apps import bird, dae, sot, tot, traq, camel

    rows = {}
    for mod in (bird, dae, tot, sot, traq):
        row = analyze_app(mod)
        times = []
        for _ in range(trials):
            _, dt, _, _ = run_once(mod.run, None, mode="plain", scale=scale)
            times.append(dt)
        row["Time_s"] = round(statistics.median(times), 3)
        rows[mod.NAME] = row

    # CaMeL: ranges across the 30 generated programs
    locs, fors, ifs, dyns = [], [], [], []
    for key, prog in camel.PROGRAMS.items():
        src = textwrap.dedent(inspect.getsource(prog.original))
        locs.append(len([l for l in src.splitlines() if l.strip()]))
        tree = ast.parse(src)
        fors.append(sum(isinstance(n, ast.For) for n in ast.walk(tree)))
        ifs.append(sum(isinstance(n, ast.If) for n in ast.walk(tree)))
        dyns.append(_count_dynamic_callsites(prog))
    rows["CaMeL (30)"] = {
        "LoC": f"{min(locs)}-{max(locs)}",
        "For": f"{min(fors)}-{max(fors)}",
        "If": f"{min(ifs)}-{max(ifs)}",
        "Dyn": f"{min(dyns)}-{max(dyns)}",
        "Ext": "2-4",
        "Time_s": "varies",
    }

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "table1.json").write_text(json.dumps(rows, indent=1))
    print(f"{'Benchmark':12s} {'LoC':>6s} {'For':>5s} {'If':>5s} "
          f"{'Dyn':>5s} {'Ext':>4s} {'Time':>8s}")
    for name, r in rows.items():
        print(f"{name:12s} {str(r['LoC']):>6s} {str(r['For']):>5s} "
              f"{str(r['If']):>5s} {str(r['Dyn']):>5s} "
              f"{str(r['Ext']):>4s} {str(r['Time_s']):>8s}")
    return rows


if __name__ == "__main__":
    run()
