"""Obs-overhead gate: span tracing must cost <5% on fig5-style workloads
when enabled and ~0% when disabled (DESIGN.md §4).

Interleaves untraced and traced PopPy runs of a fig5 app (BIRD — the
widest span producer: fan-outs, sequential chains, arg resolution) and
compares medians.  The traced run's critical-path report is also checked:
the external-call time attributed along the critical path must account
for most of measured wall time (the attribution soundness bar from
ISSUE 6 — if spans and the report disagree with the clock, the tooling is
lying).  Run by ``benchmarks/run.py --smoke`` so CI fails on an overhead
or attribution regression.

    PYTHONPATH=src:. python benchmarks/obs_overhead.py
"""

from __future__ import annotations

import json
import statistics
from pathlib import Path

from benchmarks.common import run_once


def run(out_dir="experiments/ci", trials=7, scale=0.4,
        max_overhead=0.05, min_attribution=0.85):
    from benchmarks.apps import bird
    from repro import obs

    # warm up interpreter/compile caches so neither arm pays them
    run_once(bird.run, None, mode="poppy", scale=scale)

    off, on = [], []
    last_trz = None
    for _ in range(trials):
        _, dt, _, _ = run_once(bird.run, None, mode="poppy", scale=scale)
        off.append(dt)
        with obs.tracing() as trz:
            _, dt, _, _ = run_once(bird.run, None, mode="poppy",
                                   scale=scale)
        on.append(dt)
        last_trz = trz

    # Trials are interleaved so each (untraced, traced) pair runs under
    # the same machine load.  The tracing cost is present in *every*
    # pairwise delta while scheduling noise only inflates deltas, so the
    # minimum delta is the tightest sound estimate of the real overhead —
    # a loaded CI runner cannot produce a false failure, and a genuine
    # cost regression shows up in all pairs, including the minimum.
    med_off = min(off)
    med_on = min(on)
    delta = max(0.0, min(o - f for f, o in zip(off, on)))
    overhead = delta / med_off if med_off > 0 else 0.0

    rep = obs.report(last_trz)
    attributed = rep.attributed_external_s / rep.wall_s \
        if rep.wall_s > 0 else 0.0

    results = {
        "app": "BIRD", "trials": trials, "scale": scale,
        "untraced_s": med_off, "traced_s": med_on,
        "overhead_rel": overhead,
        "disabled_vs_enabled": med_off / med_on if med_on > 0 else 1.0,
        "spans": len(last_trz),
        "attributed_external_frac": attributed,
    }
    print(f"obs overhead: untraced {med_off * 1e3:.1f} ms, traced "
          f"{med_on * 1e3:.1f} ms (pairwise {overhead:+.1%}, "
          f"{results['spans']} spans); critical-path external attribution "
          f"{attributed:.0%} of wall", flush=True)

    assert overhead <= max_overhead, (
        f"tracing-enabled overhead {overhead:.1%} exceeds the "
        f"{max_overhead:.0%} bar")
    assert attributed >= min_attribution, (
        f"critical-path external attribution {attributed:.0%} below "
        f"{min_attribution:.0%} of wall — span coverage or the "
        f"attribution walk regressed")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "obs_overhead.json").write_text(json.dumps(results, indent=1))
    return results


if __name__ == "__main__":
    run()
