"""Shared benchmark runner: executes an app under standard Python and under
PopPy with a deterministic latency-modeled LLM backend, checking result
equality and ≡_A trace equivalence on every trial (so every benchmark run
is also a soundness test)."""

from __future__ import annotations

import statistics
import time

import contextlib

from repro.core import equivalent, recording, sequential_mode
from repro.core.ai import SimulatedBackend, use_backend, use_sync_clients
from repro.core.registry import force_sequential_annotations

# latency model reported in EXPERIMENTS.md: base 30 ms + 2 ms/token with
# ±30% deterministic per-prompt jitter (time_scale rescales the whole model
# for quick runs; speedup ratios are scale-invariant modulo the fixed
# interpreter overhead, which *understates* PopPy at small scales)
DEFAULT_BACKEND = dict(base_s=0.03, per_token_s=0.002, jitter_frac=0.3)


def make_backend(scale=1.0):
    return SimulatedBackend(time_scale=scale, **DEFAULT_BACKEND)


@contextlib.contextmanager
def maybe_tracing(trace_out=None):
    """Optionally span-trace the enclosed benchmark body (DESIGN.md §4).

    Falsy ``trace_out`` → no-op (the benchmark runs exactly as before,
    tracing disabled).  Otherwise every engine/dispatch/serving span
    recorded inside the block is written to ``trace_out`` as a
    Chrome/Perfetto ``trace_event`` JSON, and the critical-path report is
    printed.  Wired to every figure benchmark's ``--trace-out`` flag.
    """
    if not trace_out:
        yield None
        return
    from repro import obs

    with obs.tracing() as trz:
        yield trz
    from pathlib import Path

    Path(trace_out).parent.mkdir(parents=True, exist_ok=True)
    obs.write_chrome_trace(trace_out, trz)
    print(f"\ntrace: {len(trz)} spans -> {trace_out} "
          f"(load in https://ui.perfetto.dev)")
    print(obs.report(trz).render())


def run_once(run_fn, arg, *, mode, scale=1.0, sync_externals=False):
    """``sync_externals=True`` swaps the async AI components for their
    blocking twins (real-world sync-SDK case): the plain baseline blocks on
    every call and PopPy overlaps them on the offload executor."""
    be = make_backend(scale)
    clients = use_sync_clients() if sync_externals else contextlib.nullcontext()
    with use_backend(be), clients, recording() as tr:
        t0 = time.perf_counter()
        if mode == "plain":
            with sequential_mode():
                result = run_fn(arg) if arg is not None else run_fn()
        elif mode == "poppy_seq":
            with force_sequential_annotations():
                result = run_fn(arg) if arg is not None else run_fn()
        else:
            result = run_fn(arg) if arg is not None else run_fn()
        dt = time.perf_counter() - t0
    return result, dt, tr, be


def bench_app(run_fn, arg=None, *, trials=3, scale=1.0, check=True,
              sync_externals=False):
    """Returns dict with median plain/poppy times, speedup, #llm calls."""
    plain_times, poppy_times = [], []
    n_calls = 0
    for t in range(trials):
        r1, dt1, tr1, be1 = run_once(run_fn, arg, mode="plain", scale=scale,
                                     sync_externals=sync_externals)
        r2, dt2, tr2, be2 = run_once(run_fn, arg, mode="poppy", scale=scale,
                                     sync_externals=sync_externals)
        plain_times.append(dt1)
        poppy_times.append(dt2)
        n_calls = len(be1.calls)
        if check:
            assert r1 == r2, f"results diverge: {r1!r} vs {r2!r}"
            ok, why = equivalent(tr1, tr2)
            assert ok, f"trace not ≡_A: {why}"
            assert len(be1.calls) == len(be2.calls)
    plain = statistics.median(plain_times)
    poppy = statistics.median(poppy_times)
    return {
        "plain_s": plain,
        "poppy_s": poppy,
        "speedup": plain / poppy if poppy > 0 else float("inf"),
        "llm_calls": n_calls,
        "trials": trials,
    }


def overhead_of(run_fn, arg=None, *, trials=3, scale=1.0):
    """Paper Fig. 7: absolute overhead of the λ^O interpreter+runtime with
    all externals forced sequential (zero extracted parallelism)."""
    plain, seq = [], []
    for t in range(trials):
        _, dt1, _, _ = run_once(run_fn, arg, mode="plain", scale=scale)
        _, dt2, _, _ = run_once(run_fn, arg, mode="poppy_seq", scale=scale)
        plain.append(dt1)
        seq.append(dt2)
    p = statistics.median(plain)
    s = statistics.median(seq)
    return {"plain_s": p, "poppy_seq_s": s, "overhead_s": s - p,
            "overhead_rel": (s - p) / p if p > 0 else 0.0}


def all_apps():
    from benchmarks.apps import bird, dae, sot, tot, traq
    return [(m.NAME, m.run, None) for m in (bird, dae, tot, sot, traq)]
