"""Fig. 10: executor offload — N-way overlap of *blocking* external calls.

The paper's speedups (§6.2) assume queued externals overlap; real-world
sync SDK clients (classic ``openai``, ``requests``) block their calling
thread, so inline dispatch on the event loop gets zero parallelism no
matter what the annotations allow.  This benchmark measures the offload
layer directly: N independent blocking externals (``time.sleep``-backed,
``delay`` seconds each) under

  * ``plain``   — standard sequential Python (``sequential_mode``),
  * ``inline``  — the engine with ``offload_policy(mode="inline")``
                  (the pre-offload runtime: serializes, overhead only),
  * ``offload`` — the engine with the default thread-offload policy.

Every trial asserts byte-identical results across all three modes.
Expected: plain ≈ inline ≈ N·delay; offload ≈ delay (+ pool overhead) —
≥3× end-to-end for N=4.
"""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

from repro.core import offload_policy, poppy, sequential_mode, unordered

from benchmarks.common import maybe_tracing


@unordered
def fetch(i: int, delay: float) -> str:
    """A blocking external: stands in for a sync SDK call."""
    time.sleep(delay)
    return f"response-{i}"


@poppy
def gather(n: int, delay: float):
    out = tuple()
    for i in range(n):
        out += (fetch(i, delay),)
    return out


def _time_once(mode: str, n: int, delay: float):
    t0 = time.perf_counter()
    if mode == "plain":
        with sequential_mode():
            result = gather(n, delay)
    elif mode == "inline":
        with offload_policy(mode="inline"):
            result = gather(n, delay)
    else:
        result = gather(n, delay)
    return result, time.perf_counter() - t0


def bench(n: int, delay: float, trials: int = 3) -> dict:
    times = {"plain": [], "inline": [], "offload": []}
    for _ in range(trials):
        ref, dt = _time_once("plain", n, delay)
        times["plain"].append(dt)
        for mode in ("inline", "offload"):
            result, dt = _time_once(mode, n, delay)
            times[mode].append(dt)
            assert result == ref, (
                f"results diverge under {mode}: {result!r} vs {ref!r}")
    med = {m: statistics.median(ts) for m, ts in times.items()}
    return {
        "n": n,
        "delay_s": delay,
        **{f"{m}_s": t for m, t in med.items()},
        "speedup": med["plain"] / med["offload"],
        "inline_speedup": med["plain"] / med["inline"],
    }


def run(out_dir="experiments/apps", trials=3, delay=0.1,
        sweep=(2, 4, 8, 16), smoke=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, delay, sweep, smoke)


def _run(out_dir, trials, delay, sweep, smoke):
    rows = []
    for n in sweep:
        r = bench(n, delay, trials=trials)
        rows.append(r)
        print(f"N={n:3d}  plain {r['plain_s']:.3f}s  "
              f"inline {r['inline_s']:.3f}s  offload {r['offload_s']:.3f}s  "
              f"offload speedup {r['speedup']:.2f}×  "
              f"(inline {r['inline_speedup']:.2f}×)", flush=True)

    four = next((r for r in rows if r["n"] == 4), None)
    # the speedup bar is skipped under --smoke (tiny N / one trial is
    # timing noise on a loaded CI runner; the result-equality asserts in
    # bench() are the smoke contract)
    if four is not None and not smoke:
        assert four["speedup"] >= 3.0, (
            f"acceptance: N=4 blocking externals must overlap ≥3×, "
            f"got {four['speedup']:.2f}×")
        print(f"\nN=4 acceptance: {four['speedup']:.2f}× ≥ 3× ✓")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig10.json").write_text(json.dumps({"rows": rows}, indent=1))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trace_out=args.trace_out)
