import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count"
                                 "=512")

"""Roofline analysis per (architecture × shape) on the single-pod mesh.

Three terms derived from compiled dry-run artifacts (TPU v5e targets:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

    T_compute    = HLO_FLOPs/device ÷ peak_FLOPs
    T_memory     = HLO_bytes/device ÷ HBM_bw
    T_collective = collective_bytes/device ÷ link_bw

XLA's ``cost_analysis()`` counts a ``while`` (lax.scan) body once, so a
full-depth scanned lowering under-reports by ~L×.  We therefore use
**block-delta costing**: lower depth-1 and depth-2 *unrolled* variants;
per-layer-group cost = (depth-2 − depth-1); fixed cost (embed/logits/loss/
non-layer optimizer work) = depth-1 − delta; total = fixed + n_groups·delta.
This is exact for homogeneous stacks (hybrid tail blocks approximated as a
pattern fraction; encoder/decoder deltas measured independently).

Also reports MODEL_FLOPS = 6·N·D (dense train; 6·N_active·D for MoE,
2·N·D for prefill/decode) and the useful-compute roofline fraction
MODEL_TIME / max(T_c, T_m, T_coll).
"""

import json
from pathlib import Path

from repro.configs import SHAPES, get_config, shape_applicable
from repro.launch.dryrun import collective_stats
from repro.launch.mesh import hardware_constants
from repro.launch.steps import lower_cell

HW = hardware_constants()


def _measure(cfg, shape, mesh):
    lowered, model, rls = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "model": model,
        "strategy": rls.tp_strategy,
    }


def _depth_variants(cfg):
    """(cfg_d1, cfg_d2, n_groups, tail_fraction) for block-delta costing."""
    if cfg.family == "hybrid":
        pat = len(cfg.block_pattern)
        n_groups = cfg.num_layers // pat
        tail = cfg.num_layers - n_groups * pat
        return (cfg.replace(num_layers=pat, scan_layers=False,
                            microbatches=1),
                cfg.replace(num_layers=2 * pat, scan_layers=False,
                            microbatches=1),
                n_groups, tail / pat)
    return (cfg.replace(num_layers=1, scan_layers=False, microbatches=1),
            cfg.replace(num_layers=2, scan_layers=False, microbatches=1),
            cfg.num_layers, 0.0)


def cell_costs(arch, shape_name, mesh):
    """Block-delta extrapolated per-device costs for the full config."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]

    if cfg.family == "enc_dec":
        base = cfg.replace(enc_layers=1, num_layers=1, scan_layers=False,
                           microbatches=1)
        if shape.kind == "train" or shape.kind == "prefill":
            m11 = _measure(base, shape, mesh)
            m21 = _measure(base.replace(enc_layers=2), shape, mesh)
            m12 = _measure(base.replace(num_layers=2), shape, mesh)
            out = {}
            for key in ("flops", "bytes", "coll_bytes"):
                de = m21[key] - m11[key]
                dd = m12[key] - m11[key]
                fixed = m11[key] - de - dd
                out[key] = fixed + cfg.enc_layers * de + cfg.num_layers * dd
            out["strategy"] = m11["strategy"]
            return out, _measure(base, shape, mesh)["model"]
        # decode touches only decoder layers
        m1 = _measure(base, shape, mesh)
        m2 = _measure(base.replace(num_layers=2), shape, mesh)
        out = {}
        for key in ("flops", "bytes", "coll_bytes"):
            d = m2[key] - m1[key]
            out[key] = (m1[key] - d) + cfg.num_layers * d
        out["strategy"] = m1["strategy"]
        return out, m1["model"]

    c1, c2, n_groups, tail_frac = _depth_variants(cfg)
    m1 = _measure(c1, shape, mesh)
    m2 = _measure(c2, shape, mesh)
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        d = m2[key] - m1[key]
        out[key] = (m1[key] - d) + (n_groups + tail_frac) * d
    out["strategy"] = m1["strategy"]
    return out, m1["model"]


def model_flops(cfg, shape, n_params):
    """Useful-compute convention: 6·N·D train, 2·N·D inference (global)."""
    if cfg.num_experts:
        # active params: replace full expert stack by top-k experts
        expert = 3 * cfg.d_model * cfg.d_ff
        n_active = n_params - cfg.num_layers * cfg.num_experts * expert \
            + cfg.num_layers * cfg.num_experts_per_tok * expert
    else:
        n_active = n_params
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens


def roofline_cell(arch, shape_name, mesh, n_devices=256):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    costs, _ = cell_costs(arch, shape_name, mesh)
    t_c = costs["flops"] / HW["peak_flops_bf16"]
    t_m = costs["bytes"] / HW["hbm_bandwidth"]
    t_x = costs["coll_bytes"] / HW["ici_link_bandwidth"]
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_x), key=lambda kv: kv[1])
    from repro.models import build_model
    mf = model_flops(cfg, shape, build_model(cfg).num_params())
    t_model = mf / (n_devices * HW["peak_flops_bf16"])
    bound = max(t_c, t_m, t_x)
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "strategy": costs["strategy"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant[0],
        "model_flops": mf,
        "hlo_flops_global": costs["flops"] * n_devices,
        "useful_flops_ratio": mf / max(costs["flops"] * n_devices, 1.0),
        "roofline_fraction": t_model / bound if bound > 0 else 0.0,
        "step_lower_bound_s": bound,
    }


def run(out_dir="experiments/roofline", archs=None, shapes=None):
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.configs import ARCH_IDS

    mesh = make_production_mesh(multi_pod=False)
    rows = []
    for arch in (archs or ARCH_IDS):
        for shape_name in (shapes or list(SHAPES)):
            try:
                rec = roofline_cell(arch, shape_name, mesh)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
            rows.append(rec)
            if rec["status"] == "ok":
                print(f"{arch:22s} {shape_name:12s} {rec['strategy']:8s} "
                      f"C {rec['t_compute_s']*1e3:9.2f}ms "
                      f"M {rec['t_memory_s']*1e3:9.2f}ms "
                      f"X {rec['t_collective_s']*1e3:9.2f}ms "
                      f"→ {rec['dominant']:10s} "
                      f"useful {rec['useful_flops_ratio']*100:5.1f}% "
                      f"roofline {rec['roofline_fraction']*100:5.1f}%",
                      flush=True)
            elif rec["status"] == "skipped":
                print(f"{arch:22s} {shape_name:12s} [skip]", flush=True)
            else:
                print(f"{arch:22s} {shape_name:12s} [ERR] {rec['error']}",
                      flush=True)
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "roofline.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    args = ap.parse_args()
    run(archs=args.arch, shapes=args.shape)
