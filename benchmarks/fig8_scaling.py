"""Fig. 8: speedup as a function of available parallelism — ToT's
BEAM_WIDTH and BIRD's per-factor assessment count."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import bench_app, maybe_tracing


def run(out_dir="experiments/apps", trials=2, scale=1.0,
        beams=(1, 2, 5, 10, 20), assessments=(1, 3, 5, 10, 20),
        trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, scale, beams, assessments)


def _run(out_dir, trials, scale, beams, assessments):
    from benchmarks.apps import bird, tot

    results = {"ToT": {}, "BIRD": {}}
    old = tot.BEAM_WIDTH
    try:
        for b in beams:
            tot.BEAM_WIDTH = b
            r = bench_app(tot.run, trials=trials, scale=scale)
            results["ToT"][b] = r
            print(f"ToT beam={b:3d}: {r['speedup']:.2f}× "
                  f"({r['llm_calls']} calls)", flush=True)
    finally:
        tot.BEAM_WIDTH = old

    old = bird.N_ASSESSMENTS
    try:
        for n in assessments:
            bird.N_ASSESSMENTS = n
            r = bench_app(bird.run, trials=trials, scale=scale)
            results["BIRD"][n] = r
            print(f"BIRD n={n:3d}: {r['speedup']:.2f}× "
                  f"({r['llm_calls']} calls)", flush=True)
    finally:
        bird.N_ASSESSMENTS = old

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig8.json").write_text(json.dumps(
        {k: {str(kk): vv for kk, vv in v.items()}
         for k, v in results.items()}, indent=1))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trace_out=args.trace_out)
