"""Fig. 11: effect-domain-keyed sequence variables (beyond-paper;
DESIGN.md §2.2, EXPERIMENTS.md §Fig. 11).

K independent agents each run a chain of N strictly ordered steps:
``think`` (an @unordered llm call) feeding ``commit`` — a slow
@sequential external (a per-agent DB/memory persistence write).  Under
the paper's single sequence variable every ``commit`` serializes against
every other, so the program costs ~K·N commit latencies.  With
``effects=("db:{agent}",)`` each agent's chain is its own lock domain:
chains overlap and the program costs ~N.

Three runs per trial, all on the same deterministic backend:

  plain    standard sequential Python (the semantic oracle)
  single   PopPy, commits declared with no effect domains ("*" — the
           paper's single-chain behavior)
  keyed    PopPy, commits keyed per agent

Every trial asserts byte-identical results across all three runs and
per-domain ≡_A trace equivalence of the keyed run against the oracle.
The acceptance bar is keyed ≥3× over single at K=4.

    PYTHONPATH=src:. python benchmarks/fig11_effect_domains.py
"""

from __future__ import annotations

import asyncio
import json
import statistics
import time
from pathlib import Path

from repro.core import equivalent, poppy, recording, sequential, \
    sequential_mode
from repro.core.ai import llm, use_backend

from benchmarks.common import make_backend, maybe_tracing

K_AGENTS = 4
N_STEPS = 6
COMMIT_S = 0.03


class _World:
    """The persistence layer: per-agent append-only logs with a slow
    sequential ``commit``.  ``keyed=True`` declares per-agent effect
    domains; ``keyed=False`` reproduces the single-chain behavior."""

    def __init__(self, keyed: bool, commit_s: float = COMMIT_S):
        self.logs: dict = {}
        self.in_flight = 0
        self.max_in_flight = 0
        world = self
        effects = ("db:{agent}",) if keyed else None

        @sequential(effects=effects, returns_immutable=True)
        async def commit(agent, text):
            world.in_flight += 1
            world.max_in_flight = max(world.max_in_flight, world.in_flight)
            await asyncio.sleep(commit_s)
            world.in_flight -= 1
            world.logs.setdefault(agent, []).append(text)
            return f"{agent}#{len(world.logs[agent])}"

        commit.__name__ = commit.__qualname__ = "commit"
        commit.__poppy_external__.name = "commit"
        self.commit = commit

    def snapshot(self):
        return {k: tuple(v) for k, v in sorted(self.logs.items())}


def _make_app(world, k_agents, n_steps):
    commit = world.commit

    @poppy
    def chains():
        receipts = ()
        for a in range(k_agents):
            prev = "start"
            for s in range(n_steps):
                thought = llm(f"agent{a} step{s}: {prev}", max_tokens=8)
                prev = commit(f"agent{a}", thought)
            receipts += (prev,)
        return receipts

    return chains


def _run_once(plain, keyed, *, k_agents, n_steps, scale, commit_s):
    world = _World(keyed, commit_s=commit_s)
    app = _make_app(world, k_agents, n_steps)
    be = make_backend(scale)
    with use_backend(be), recording() as tr:
        t0 = time.perf_counter()
        if plain:
            with sequential_mode():
                result = app()
        else:
            result = app()
        dt = time.perf_counter() - t0
    return result, world.snapshot(), dt, tr, world


def bench(k_agents=K_AGENTS, n_steps=N_STEPS, *, trials=3, scale=0.2,
          commit_s=COMMIT_S):
    times = {"plain": [], "single": [], "keyed": []}
    overlap = 0
    kw = dict(k_agents=k_agents, n_steps=n_steps, scale=scale,
              commit_s=commit_s)
    for _ in range(trials):
        # the ≡_A oracle must carry the same *declarations* as the run it
        # is compared against (effect keys are part of the trace), so each
        # PopPy configuration gets a sequential oracle with matching
        # annotations; results must be byte-identical across all of them
        r_ref, logs_ref, dt, _, _ = _run_once(True, False, **kw)
        times["plain"].append(dt)
        for mode, keyed in (("single", False), ("keyed", True)):
            r_or, logs_or, _, tr_or, _ = _run_once(True, keyed, **kw)
            assert r_or == r_ref and logs_or == logs_ref, (
                "effect declarations changed plain-Python results")
            r, logs, dt, tr, world = _run_once(False, keyed, **kw)
            times[mode].append(dt)
            assert r == r_ref, f"{mode}: results diverge: {r!r} vs {r_ref!r}"
            assert logs == logs_ref, f"{mode}: logs diverge"
            ok, why = equivalent(tr_or, tr)
            assert ok, f"{mode}: trace not ≡_A: {why}"
            if mode == "keyed":
                overlap = max(overlap, world.max_in_flight)
    med = {m: statistics.median(ts) for m, ts in times.items()}
    return {
        "k_agents": k_agents,
        "n_steps": n_steps,
        "commit_s": commit_s,
        **{f"{m}_s": t for m, t in med.items()},
        "speedup_vs_single": med["single"] / med["keyed"],
        "speedup_vs_plain": med["plain"] / med["keyed"],
        "max_commit_overlap": overlap,
    }


def run(out_dir="experiments/apps", trials=3, scale=0.2,
        sweep=(1, 2, 4, 8), n_steps=N_STEPS, smoke=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, scale, sweep, n_steps, smoke)


def _run(out_dir, trials, scale, sweep, n_steps, smoke):
    rows = []
    for k in sweep:
        r = bench(k, n_steps, trials=trials, scale=scale)
        rows.append(r)
        print(f"K={k:2d}  plain {r['plain_s']:.3f}s  single "
              f"{r['single_s']:.3f}s  keyed {r['keyed_s']:.3f}s  "
              f"keyed/single {r['speedup_vs_single']:.2f}×  "
              f"(commit overlap {r['max_commit_overlap']})", flush=True)

    four = next((r for r in rows if r["k_agents"] == 4), None)
    if four is not None and not smoke:
        assert four["speedup_vs_single"] >= 3.0, (
            f"acceptance: K=4 independent sequential chains must run ≥3× "
            f"faster keyed than single-domain, got "
            f"{four['speedup_vs_single']:.2f}×")
        print(f"\nK=4 acceptance: {four['speedup_vs_single']:.2f}× ≥ 3× ✓")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig11.json").write_text(json.dumps({"rows": rows}, indent=1))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, scale=args.scale, trace_out=args.trace_out)
