"""Fig. 12: auto-batching of pending unordered externals (beyond-paper;
DESIGN.md §2.3, EXPERIMENTS.md §Fig. 12).

A RAG-style app: an embedding fan-out over N docs (plus the query), a
similarity computation, a map-style LLM summarization of every doc, and a
combine call.  The backend models a real serving endpoint with
server-side batching: every request costs ``request_s + per_item_s·n``
inside one of ``max_concurrency`` admission units, and it accepts list
payloads — so a batch of n costs *one* admission and one request
overhead where n singles cost n of each.

Three runs per trial, all on the same deterministic backend:

  plain      standard sequential Python (the semantic oracle)
  unbatched  PopPy opportunistic execution, one request per call
  batched    PopPy + ``batching()``: the engine's queue-time windows
             coalesce each fan-out into one batched request

Every trial asserts byte-identical results across all three runs and ≡_A
trace equivalence of both PopPy runs against the oracle.  The acceptance
bar is batched ≥3× over unbatched at N=32.

    PYTHONPATH=src:. python benchmarks/fig12_autobatch.py
"""

from __future__ import annotations

import asyncio
import json
import statistics
import time
from pathlib import Path

from repro.core import batching, equivalent, poppy, recording, \
    sequential_mode
from repro.core.ai import SimulatedBackend, embed, llm, use_backend, \
    use_dispatcher
from repro.dispatch import Dispatcher

from benchmarks.common import maybe_tracing

N_DOCS = 32
REQUEST_S = 0.05
PER_ITEM_S = 0.001
MAX_CONCURRENCY = 2


class BatchyBackend(SimulatedBackend):
    """A latency model where per-request overhead dominates: request cost
    ``request_s + per_item_s·n_items`` inside one of ``max_concurrency``
    concurrent admission units (the shape of a real LLM/embedding API,
    whose server batches internally and rate-limits requests).  Responses
    are the deterministic ``SimulatedBackend`` ones, so batched, unbatched,
    and sequential runs are comparable call-for-call."""

    def __init__(self, *, scale=1.0, request_s=REQUEST_S,
                 per_item_s=PER_ITEM_S, max_concurrency=MAX_CONCURRENCY):
        super().__init__(time_scale=scale)
        self.request_s = request_s
        self.per_item_s = per_item_s
        self._sem = asyncio.Semaphore(max_concurrency)

    async def _request(self, keys):
        async with self._sem:
            for k in keys:
                self._enter(k)
            try:
                await asyncio.sleep(
                    (self.request_s + self.per_item_s * len(keys))
                    * self.time_scale)
            finally:
                for _ in keys:
                    self._exit()

    async def generate(self, prompt, *, max_tokens, temperature, stop):
        await self._request([prompt])
        return self.response(prompt, max_tokens)

    async def embed(self, text):
        await self._request([text])
        return self._embedding(text)

    async def generate_batch(self, prompts, *, max_tokens, temperature,
                             stop):
        prompts = list(prompts)
        with self._count_lock:
            self.batches.append(len(prompts))
        await self._request(prompts)
        return [self.response(p, max_tokens) for p in prompts]

    async def embed_batch(self, texts):
        texts = list(texts)
        with self._count_lock:
            self.batches.append(len(texts))
        await self._request(texts)
        return [self._embedding(t) for t in texts]


@poppy
def rag(docs, query):
    vecs = ()
    for d in docs:
        vecs += (embed(d),)          # fan-out: one batch window
    qv = embed(query)
    sims = ()
    for v in vecs:
        s = 0.0
        for j in range(8):
            s += v[j] * qv[j]
        sims += (round(s, 3),)
    summaries = ()
    k = 0
    for d in docs:                   # map step: a second batch window
        summaries += (llm(f"summarize[{sims[k]}] {d}", max_tokens=8),)
        k += 1
    return llm(f"combine: {summaries}", max_tokens=16)


def _run_once(mode, docs, query, scale):
    be = BatchyBackend(scale=scale)
    d = Dispatcher()
    with use_backend(be), use_dispatcher(d), recording() as tr:
        t0 = time.perf_counter()
        if mode == "plain":
            with sequential_mode():
                result = rag(docs, query)
        elif mode == "batched":
            with batching():
                result = rag(docs, query)
        else:
            result = rag(docs, query)
        dt = time.perf_counter() - t0
    return result, dt, tr, be, d


def bench(n_docs=N_DOCS, *, trials=3, scale=1.0):
    docs = tuple(f"document {i} about topic {i % 5}" for i in range(n_docs))
    query = "what do the documents say?"
    times = {"plain": [], "unbatched": [], "batched": []}
    batch_sizes = []
    for _ in range(trials):
        r_ref, dt, tr_ref, be_ref, _ = _run_once("plain", docs, query, scale)
        times["plain"].append(dt)
        n_calls = len(be_ref.calls)
        for mode in ("unbatched", "batched"):
            r, dt, tr, be, d = _run_once(mode, docs, query, scale)
            times[mode].append(dt)
            assert r == r_ref, f"{mode}: results diverge: {r!r} vs {r_ref!r}"
            ok, why = equivalent(tr_ref, tr)
            assert ok, f"{mode}: trace not ≡_A: {why}"
            assert len(be.calls) == n_calls, (
                f"{mode}: element count diverges: "
                f"{len(be.calls)} vs {n_calls}")
            if mode == "batched":
                assert be.batches, "batched run produced no batches"
                batch_sizes = sorted(be.batches, reverse=True)
            else:
                assert not be.batches, "unbatched run batched?!"
    med = {m: statistics.median(ts) for m, ts in times.items()}
    return {
        "n_docs": n_docs,
        "request_s": REQUEST_S,
        "per_item_s": PER_ITEM_S,
        "max_concurrency": MAX_CONCURRENCY,
        **{f"{m}_s": t for m, t in med.items()},
        "speedup_batched_vs_unbatched": med["unbatched"] / med["batched"],
        "speedup_batched_vs_plain": med["plain"] / med["batched"],
        "speedup_unbatched_vs_plain": med["plain"] / med["unbatched"],
        "batch_sizes": batch_sizes,
    }


def run(out_dir="experiments/apps", trials=3, n_docs=N_DOCS, scale=1.0,
        smoke=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, n_docs, scale, smoke)


def _run(out_dir, trials, n_docs, scale, smoke):
    r = bench(n_docs, trials=trials, scale=scale)
    print(f"N={r['n_docs']:3d}  plain {r['plain_s']:.3f}s  unbatched "
          f"{r['unbatched_s']:.3f}s  batched {r['batched_s']:.3f}s  "
          f"batched/unbatched {r['speedup_batched_vs_unbatched']:.2f}×  "
          f"(batches: {r['batch_sizes']})", flush=True)
    # the speedup bar is skipped under --smoke (tiny N / one trial is
    # timing noise); result equality and ≡_A were asserted every trial
    if not smoke:
        assert r["speedup_batched_vs_unbatched"] >= 3.0, (
            f"acceptance: auto-batching must be ≥3× over unbatched "
            f"opportunistic execution at N={n_docs}, got "
            f"{r['speedup_batched_vs_unbatched']:.2f}×")
        print(f"\nN={n_docs} acceptance: "
              f"{r['speedup_batched_vs_unbatched']:.2f}× ≥ 3× ✓")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig12.json").write_text(json.dumps(r, indent=1))
    return r


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--n-docs", type=int, default=N_DOCS)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, scale=args.scale, n_docs=args.n_docs,
        trace_out=args.trace_out)
