"""Fig. 17: durability under chaos (beyond-paper; DESIGN.md §2.5,
EXPERIMENTS.md §Fig. 17).

Three legs, all differential against an uninterrupted run:

  recovery  write-ahead journal kill/resume: a child process runs the
            pipeline with ``Journal(kill_after=K)`` and hard-exits
            (``os._exit``) the instant the K-th committed external lands
            on disk — a SIGKILL mid-run as far as the journal can tell.
            The parent resumes from the surviving journal and asserts
            the final result is byte-identical to the uninterrupted
            oracle, the trace stays ≡_A, and at least ``REPLAY_FLOOR``
            of the resumed run's externals were served from the journal
            instead of re-executing.
  faults    seeded fault injection through the dispatcher: a 20%%
            error-rate plan with retries absorbing every draw — asserts
            result equality with the healthy run, zero leaked dispatcher
            admissions / in-flight backend slots, and the circuit
            breaker's full open → half-open probe → close cycle when a
            backend dies and heals.
  serving   injected failures in front of the tiny JAX serving engine:
            every perturbed request must leave decode slots and
            KV-page/prefix-pin counters exactly balanced.

    PYTHONPATH=src:. python benchmarks/fig17_durability.py
"""

from __future__ import annotations

import asyncio
import hashlib
import json
import os
import statistics
import subprocess
import sys
import tempfile
import time
from pathlib import Path

#: Acceptance floor: fraction of the resumed run's externals that must be
#: served from the journal (the chaos kill point is chosen so an honest
#: replay clears this with margin).
REPLAY_FLOOR = 0.8
TOPICS = ("alpha", "beta", "gamma", "delta", "epsilon", "zeta")
CALL_S = 0.01
KILL_AFTER = 15   # of the pipeline's 18 journaled resolutions

EFFECTS: list = []
CALLS: list = []
_DELAY = {"s": CALL_S}


def _digest(text):
    return int.from_bytes(
        hashlib.sha256(str(text).encode()).digest()[:4], "big")


# -- the durable pipeline (module-level so child and parent share keys) ------

from repro.core import (equivalent, poppy, recording, sequential,  # noqa: E402
                        sequential_mode, unordered)
from repro.durability import (KILL_EXIT, Journal, resume,  # noqa: E402
                              use_journal)


@unordered(returns_immutable=True)
def research(topic):
    CALLS.append(("research", topic))
    time.sleep(_DELAY["s"])
    return f"research({topic})#{_digest(topic) % 997}"


@unordered(returns_immutable=True)
def summarize(text):
    CALLS.append(("summarize", text))
    time.sleep(_DELAY["s"])
    return f"sum#{_digest(text) % 997}"


@sequential(effects=("report",))
def save(entry):
    EFFECTS.append(entry)
    return None


@poppy
def pipeline(topics):
    acc = ()
    for t in topics:
        r = research(t)
        s = summarize(r)
        save(s)
        acc += (s,)
    return "|".join(acc)


def _reset():
    CALLS.clear()
    EFFECTS.clear()


# -- leg 1: kill/resume recovery --------------------------------------------


def _child_main(journal_path, kill_after):
    """Run the pipeline, hard-exiting after ``kill_after`` journal
    appends.  Reaching the end means the kill never fired — exit 0 so the
    parent can tell the difference."""
    with use_journal(Journal(journal_path, mode="record",
                             kill_after=kill_after)):
        pipeline(TOPICS)
    return 0


def _spawn_killed_child(journal_path, kill_after):
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root), str(root / "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return subprocess.run(
        [sys.executable, str(Path(__file__).resolve()),
         "--child", str(journal_path), "--kill-after", str(kill_after)],
        env=env, capture_output=True, text=True, timeout=120)


def bench_recovery(*, trials=2, kill_after=KILL_AFTER):
    frac_min = 1.0
    times = {"full": [], "resume": []}
    for _ in range(trials):
        # uninterrupted oracle (plain + engine, both in-process)
        _reset()
        with sequential_mode(), recording() as tr_plain:
            expect = pipeline(TOPICS)
        fx_plain = list(EFFECTS)
        _reset()
        t0 = time.perf_counter()
        with recording() as tr_full:
            full = pipeline(TOPICS)
        times["full"].append(time.perf_counter() - t0)
        assert full == expect

        # killed child: dies via os._exit(KILL_EXIT) mid-journal
        tmp = Path(tempfile.mkdtemp(prefix="fig17_"))
        jp = tmp / "run.journal"
        proc = _spawn_killed_child(jp, kill_after)
        assert proc.returncode == KILL_EXIT, (
            f"child should die at append #{kill_after} with exit "
            f"{KILL_EXIT}, got {proc.returncode}\n{proc.stderr[-2000:]}")
        lines = [ln for ln in jp.read_text().splitlines() if ln.strip()]
        assert len(lines) >= kill_after, (
            f"journal short: {len(lines)} < {kill_after}")

        # resume: byte-identical completion, mostly served from disk
        _reset()
        t0 = time.perf_counter()
        with recording() as tr_res, resume(jp) as jr:
            resumed = pipeline(TOPICS)
        times["resume"].append(time.perf_counter() - t0)
        assert resumed == expect, (
            f"resumed result diverges: {resumed!r} != {expect!r}")
        for tag, tr in (("full", tr_full), ("resume", tr_res)):
            ok, why = equivalent(tr_plain, tr)
            assert ok, f"{tag}: trace not ≡_A: {why}"
        st = jr.stats
        assert st.loaded >= kill_after, st
        total = st.replayed + len(CALLS) + len(EFFECTS)
        frac = st.replayed / total if total else 0.0
        frac_min = min(frac_min, frac)
        assert frac >= REPLAY_FLOOR, (
            f"replay fraction {frac:.2f} below floor {REPLAY_FLOOR} "
            f"({st.replayed} replayed of {total} externals)")
        # a resumed run is itself resumable: it appended the tail
        assert st.appended >= 1, st
    return {
        "kill_after": kill_after,
        "full_s": statistics.median(times["full"]),
        "resume_s": statistics.median(times["resume"]),
        "recovery_replay_fraction": frac_min,
        "resume_speedup": (statistics.median(times["full"])
                           / statistics.median(times["resume"])),
    }


# -- leg 2: dispatcher fault injection + circuit breaker ---------------------


def bench_faults(*, trials=2):
    from repro.core.ai import SimulatedBackend
    from repro.dispatch import Dispatcher, RetryPolicy
    from repro.dispatch.reliability import BreakerPolicy, CircuitOpenError
    from repro.durability.faults import (FaultInjector, FaultPlan,
                                         InjectedFault)

    injected = 0
    for trial in range(trials):
        async def chaos_run(trial=trial):
            prompts = [f"fault-{trial}-{i}" for i in range(16)]
            kw = dict(max_tokens=6, temperature=0.0, stop=None)
            # healthy oracle
            be0 = SimulatedBackend(time_scale=0.01)
            d0 = Dispatcher([be0])
            healthy = await asyncio.gather(
                *(d0.generate(p, **kw) for p in prompts))
            # chaos run: seeded 20% error rate, retries absorb every draw
            be = SimulatedBackend(time_scale=0.01)
            d = Dispatcher([be],
                           retry=RetryPolicy(max_attempts=8, base_s=0.001),
                           faults=FaultPlan(error_rate=0.2, seed=7))
            chaotic = await asyncio.gather(
                *(d.generate(p, **kw) for p in prompts))
            assert chaotic == healthy, "faulty run diverged from healthy"
            st = d.stats
            assert st.faults_injected > 0, "plan injected nothing"
            # zero leaks: no queued admission, no in-flight slot
            assert st.queue_depth == 0
            for r in d.router.replicas:
                assert r.outstanding == 0, f"leaked slot on {r.name}"
            assert be._in_flight == 0
            return st.faults_injected

        async def breaker_cycle():
            be = SimulatedBackend(time_scale=0.01)
            fi = FaultInjector(FaultPlan(error_rate=1.0, seed=3))
            d = Dispatcher([be],
                           breaker=BreakerPolicy(failure_threshold=3,
                                                 cooldown_s=0.05),
                           faults=fi)
            kw = dict(max_tokens=6, temperature=0.0, stop=None)
            for i in range(5):
                try:
                    await d.generate(f"dead-{i}", **kw)
                except (InjectedFault, CircuitOpenError):
                    pass
            st = d.stats
            assert st.breaker_opens >= 1, "breaker never opened"
            assert st.breaker_fastfails >= 1, "open circuit never fast-failed"
            fi.plan = FaultPlan()          # the backend heals
            await asyncio.sleep(0.06)      # past the cooldown
            out = await d.generate("healed", **kw)
            assert out, "probe request failed after heal"
            assert st.breaker_probes >= 1 and st.breaker_closes >= 1, (
                "breaker never probed/closed after heal")
            for r in d.router.replicas:
                assert r.outstanding == 0

        injected += asyncio.run(chaos_run())
        asyncio.run(breaker_cycle())
    return {"faults_injected": injected, "trials": trials}


# -- leg 3: serving-engine leak check under injected failures ---------------


def bench_serving_leaks():
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.backend import LocalEngineBackend
    from repro.serving.engine import ServingEngine
    from repro.durability.faults import FaultPlan, InjectedFault

    cfg = get_config("stablelm-3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    engine = ServingEngine(model, params, max_slots=4, max_len=64)
    free0 = len(engine.free_slots)

    def cache_pages():
        pc = engine.prefix_cache
        return pc.stats().get("pages", 0) if pc is not None else 0

    def pages_free():
        return engine.stats().get("paged", {}).get("pages_free")

    pages0, cached0 = pages_free(), cache_pages()
    be = LocalEngineBackend(engine,
                            faults=FaultPlan(error_rate=0.5, seed=11))

    async def drive():
        ok = fail = 0
        for i in range(12):
            try:
                await be.generate(f"chaos prompt {i}", max_tokens=4,
                                  temperature=0.0, stop=None)
                ok += 1
            except InjectedFault:
                fail += 1
        return ok, fail

    ok, fail = asyncio.run(drive())
    assert ok > 0 and fail > 0, f"need both outcomes, got {ok}/{fail}"
    assert len(engine.free_slots) == free0, (
        f"leaked decode slots: {len(engine.free_slots)} != {free0}")
    assert not engine.active, f"requests stuck active: {engine.active}"
    if pages0 is not None:
        # pages missing from the free list must be exactly the ones the
        # prefix cache retained on purpose — nothing held by a dead request
        taken = pages0 - pages_free()
        retained = cache_pages() - cached0
        assert taken == retained, (
            f"leaked KV pages: {taken} gone from free list, only "
            f"{retained} retained by the prefix cache")
    return {"requests_ok": ok, "requests_faulted": fail}


# -- harness ----------------------------------------------------------------


def run(out_dir="experiments/apps", trials=2, kill_after=KILL_AFTER,
        smoke=False):
    rec = bench_recovery(trials=trials, kill_after=kill_after)
    print(f"recovery  full {rec['full_s']:.3f}s  resume "
          f"{rec['resume_s']:.3f}s  replay fraction "
          f"{rec['recovery_replay_fraction']:.2f}  "
          f"({rec['resume_speedup']:.2f}× faster)", flush=True)
    fl = bench_faults(trials=trials)
    print(f"faults    {fl['faults_injected']} injected over "
          f"{fl['trials']} trials; results equal, slots balanced, "
          f"breaker cycled open→probe→close", flush=True)
    sv = bench_serving_leaks()
    print(f"serving   {sv['requests_ok']} ok / {sv['requests_faulted']} "
          f"faulted; decode slots and KV pages balanced", flush=True)

    assert rec["recovery_replay_fraction"] >= REPLAY_FLOOR
    if not smoke:
        print(f"\nacceptance: replay fraction "
              f"{rec['recovery_replay_fraction']:.2f} ≥ {REPLAY_FLOOR} ✓")

    result = {"recovery": rec, "faults": fl, "serving": sv}
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig17.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default=None,
                    help="(internal) run the kill-mode child against this "
                         "journal path")
    ap.add_argument("--kill-after", type=int, default=KILL_AFTER)
    ap.add_argument("--trials", type=int, default=2)
    args = ap.parse_args()
    if args.child:
        raise SystemExit(_child_main(args.child, args.kill_after))
    run(trials=args.trials, kill_after=args.kill_after)
