"""Tree of Thoughts [Yao et al. 2023] — paper Fig. 1, faithfully:
beam search where an LLM proposes successor states and scores them, with a
value cache and ordered logging."""

from repro.core import poppy, sequential
from repro.core.ai import llm

NAME = "ToT"
OUT = []


@sequential
def emit(line):
    OUT.append(line)
    return None


NUM_STEPS = 3
BEAM_WIDTH = 5


@poppy
def tree_of_thoughts(task):
    states = ("",)
    for step in range(NUM_STEPS):
        new_states = tuple()
        for s in states:
            new_states += llm_get_proposals(task, s)
        values = get_values(task, new_states)
        states = topk(new_states, values, BEAM_WIDTH)
        emit(f"step {step}: {states}")
    return states


@poppy
def get_values(task, states):
    value_cache = frozenset()
    values = tuple()
    for idx, state in enumerate(states):
        if state in value_cache:
            value = 0
            emit(f"{idx}: duplicate")
        else:
            value = llm_get_value(task, state)
            value_cache |= {state}
            emit(f"{idx}: {value}")
        values += (value,)
    return values


@poppy
def llm_get_proposals(task, state):
    r = llm(f"propose next thoughts | task: {task} | state: {state}",
            max_tokens=24)
    return tuple(r.split())


@poppy
def llm_get_value(task, state):
    r = llm(f"rate 1-10 | task: {task} | state: {state}", max_tokens=4)
    return len(r)


@poppy
def topk(states, values, k):
    pairs = sorted(zip(values, states), reverse=True)
    out = tuple()
    for v, s in pairs[:k]:
        out += (s,)
    return out


DEFAULT_INPUT = "solve 24 with 4 4 6 8"
ENTRY = tree_of_thoughts
FUNCS = [tree_of_thoughts, get_values, llm_get_proposals, llm_get_value,
         topk]
EXTERNALS = ["llm", "emit"]


def run(task=DEFAULT_INPUT):
    OUT.clear()
    return ENTRY(task)
