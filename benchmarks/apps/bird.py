"""BIRD [Feng et al. 2025]: Bayesian inference from abduction and
deduction — an LLM abduces factors for a query, multiple LLM calls assess
each factor's evidence (the parallelizable hyperparameter of paper §8.4),
and a small Bayesian combination produces a calibrated probability."""

from repro.core import poppy, sequential
from repro.core.ai import llm

NAME = "BIRD"
OUT = []


@sequential
def emit(line):
    OUT.append(line)
    return None


N_FACTORS = 4
N_ASSESSMENTS = 3   # LLM calls per factor (paper varies this 1..20)


@poppy
def abduce_factors(query):
    r = llm(f"list {N_FACTORS} factors relevant to: {query}", max_tokens=24)
    words = r.split()
    factors = tuple()
    for i in range(N_FACTORS):
        if i < len(words):
            factors += (words[i],)
        else:
            factors += (f"factor{i}",)
    return factors


@poppy
def assess_factor(query, factor, n):
    votes = tuple()
    for i in range(n):
        r = llm(f"does factor '{factor}' support '{query}'? "
                f"assessment {i}", max_tokens=6)
        votes += (len(r) % 2,)
    return votes


@poppy
def bird(query):
    factors = abduce_factors(query)
    all_votes = tuple()
    for f in factors:
        votes = assess_factor(query, f, N_ASSESSMENTS)
        s = 0
        for v in votes:
            s += v
        emit(f"factor {f}: {s}/{N_ASSESSMENTS}")
        all_votes += (s,)
    # Bayesian-ish combination: product of per-factor odds
    num = 1.0
    den = 1.0
    for s in all_votes:
        p = (s + 1) / (N_ASSESSMENTS + 2)
        num *= p
        den *= (1 - p)
    prob = num / (num + den)
    emit(f"p = {prob:.3f}")
    return prob


DEFAULT_INPUT = "will it rain tomorrow in Seattle?"
ENTRY = bird
FUNCS = [bird, abduce_factors, assess_factor]
EXTERNALS = ["llm", "emit"]


def run(query=DEFAULT_INPUT):
    OUT.clear()
    return ENTRY(query)
