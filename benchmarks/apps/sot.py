"""Skeleton of Thought [Ning et al. 2024]: one LLM call drafts an answer
skeleton; each skeleton point expands with an independent LLM call.  The
original implementation never actually ran in parallel (paper §9) —
PopPy extracts the intended parallelism from the sequential code."""

from repro.core import poppy, sequential
from repro.core.ai import llm

NAME = "SoT"
OUT = []


@sequential
def emit(line):
    OUT.append(line)
    return None


N_POINTS = 6


@poppy
def skeleton_of_thought(question):
    skeleton = llm(f"outline {N_POINTS} short bullet points for: "
                   f"{question}", max_tokens=32)
    points = skeleton.split()
    answer = tuple()
    for idx, point in enumerate(points[:N_POINTS]):
        expansion = llm(f"expand point '{point}' of question {question}",
                        max_tokens=48)
        emit(f"point {idx} done")
        answer += ((point, expansion),)
    return answer


DEFAULT_INPUT = "how do solar panels work?"
ENTRY = skeleton_of_thought
FUNCS = [skeleton_of_thought]
EXTERNALS = ["llm", "emit"]


def run(question=DEFAULT_INPUT):
    OUT.clear()
    return ENTRY(question)
