"""Diverse Agent Entropy [Feng et al. 2025]: several agents answer a
question from diverse perspectives, debate in rounds while seeing each
other's answers, and converge; uncertainty is the answer-distribution
entropy."""

from repro.core import poppy, sequential
from repro.core.ai import llm

NAME = "DAE"
OUT = []


@sequential
def emit(line):
    OUT.append(line)
    return None


N_AGENTS = 5
N_ROUNDS = 2
PERSPECTIVES = ("scientist", "historian", "engineer", "economist", "critic")


@poppy
def agent_answer(question, persona, context):
    r = llm(f"as a {persona}, answer briefly: {question} | context: "
            f"{context}", max_tokens=12)
    return r.split()[0] if r else "unknown"


@poppy
def debate(question):
    answers = tuple()
    for i in range(N_AGENTS):
        a = agent_answer(question, PERSPECTIVES[i], "")
        answers += (a,)
    for rnd in range(N_ROUNDS):
        emit(f"round {rnd}: {answers}")
        revised = tuple()
        for i in range(N_AGENTS):
            others = answers[:i] + answers[i + 1:]
            a = agent_answer(question, PERSPECTIVES[i],
                             f"other agents said {others}")
            revised += (a,)
        answers = revised
    counts = {}
    for a in answers:
        counts[a] = counts.get(a, 0) + 1
    best = None
    best_n = 0
    for a, n in sorted(counts.items()):
        if n > best_n:
            best, best_n = a, n
    emit(f"final: {best} ({best_n}/{N_AGENTS})")
    return (best, best_n, len(counts))


DEFAULT_INPUT = "what is the boiling point of water at sea level?"
ENTRY = debate
FUNCS = [debate, agent_answer]
EXTERNALS = ["llm", "emit"]


def run(question=DEFAULT_INPUT):
    OUT.clear()
    return ENTRY(question)
