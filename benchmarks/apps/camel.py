"""CaMeL-style generated programs [Debenedetti et al. 2026].

CaMeL has an LLM emit a small Python program per AgentDojo "workspace"
task and executes it.  We reproduce the *shape* of that suite: 30 small
generated programs over a mock workspace (files, calendar, email) —
some make zero LLM calls, some fan out over drive files, some chain
dependent calls — matching Table 1's ranges (LoC 2–114, 0–8 externals).
Programs are generated deterministically from their index."""

from repro.core import poppy, readonly, sequential
from repro.core.ai import llm

NAME = "CaMeL"
OUT = []


class Workspace:
    def __init__(self):
        self.files = {
            f"file{i}.txt": f"contents of file {i} "
                            + ("vacation plans june" if i == 3 else "notes")
            for i in range(6)
        }
        self.calendar = [f"meeting {i} on day {i}" for i in range(4)]
        self.sent = []


WS = Workspace()


@sequential
def emit(line):
    OUT.append(line)
    return None


@readonly
def list_files():
    return tuple(sorted(WS.files))


@readonly
def read_file(name):
    return WS.files.get(name, "")


@sequential
def write_file(name, contents):
    WS.files[name] = contents
    return None


@readonly
def get_calendar():
    return tuple(WS.calendar)


@sequential
def send_email(to, body):
    WS.sent.append((to, body))
    return None


def _make_program(i: int):
    """Deterministically build program variant i (0..29)."""
    kind = i % 6

    if kind == 0:
        # no LLM calls: pure workspace manipulation (PopPy overhead case)
        @poppy
        def prog():
            names = list_files()
            n = 0
            for name in names:
                body = read_file(name)
                n += len(body)
            emit(f"total {n}")
            return n
    elif kind == 1:
        # single LLM call (CaMeL-28-like: overhead hidden by the call)
        @poppy
        def prog():
            doc = read_file("file1.txt")
            score = llm(f"extract feedback score from: {doc}", max_tokens=4)
            emit(score)
            return score
    elif kind == 2:
        # fan-out over drive files (CaMeL-36-like: parallelizable)
        @poppy
        def prog():
            names = list_files()
            found = tuple()
            for name in names:
                body = read_file(name)
                verdict = llm(f"is this a vacation plan? {body}",
                              max_tokens=3)
                if len(verdict) % 2 == 0:
                    found += (name,)
            emit(f"candidates: {found}")
            return found
    elif kind == 3:
        # two independent generations from one source + a write
        @poppy
        def prog():
            body = read_file("file3.txt")
            summary = llm(f"what happens on june 13 per: {body}",
                          max_tokens=16)
            packing = llm(f"make a packing list from: {body}",
                          max_tokens=16)
            write_file("packing.txt", packing)
            emit(summary)
            return (summary, packing)
    elif kind == 4:
        # dependent chain (not parallelizable)
        @poppy
        def prog():
            events = get_calendar()
            pick = llm(f"which event matters most: {events}", max_tokens=8)
            draft = llm(f"draft an email about {pick}", max_tokens=16)
            send_email("boss@example.com", draft)
            emit("sent")
            return draft
    else:
        # mixed: calendar fan-out + summary reduction
        @poppy
        def prog():
            events = get_calendar()
            notes = tuple()
            for e in events:
                note = llm(f"one-line prep note for {e}", max_tokens=8)
                notes += (note,)
            combined = llm(f"merge notes: {notes}", max_tokens=16)
            emit(combined)
            return combined

    prog.original.__qualname__ = f"camel_{i:02d}"
    return prog


PROGRAMS = {f"C-{i+1}": _make_program(i) for i in range(30)}


def makes_llm_calls(key: str) -> bool:
    i = int(key.split("-")[1]) - 1
    return i % 6 != 0


def run(key: str):
    OUT.clear()
    global WS
    WS = Workspace()
    return PROGRAMS[key]()
