"""TRAQ [Li et al. 2024]: trustworthy retrieval-augmented QA — embed the
query, retrieve top-k documents from a vector store (embedding calls
parallelize), generate multiple answers per document (parallel LLM calls),
cluster the answers, and emit a conformal answer set."""

from repro.core import poppy, sequential, unordered
from repro.core.ai import embed, llm

NAME = "TRAQ"
OUT = []

_DOCS = tuple(
    f"document {i} about topic {t}"
    for i, t in enumerate(("solar", "wind", "hydro", "nuclear", "coal",
                           "gas", "geothermal", "biomass")))


@sequential
def emit(line):
    OUT.append(line)
    return None


@unordered
def dot(a, b):
    return sum(x * y for x, y in zip(a, b))


TOP_K = 3
GEN_PER_DOC = 2


@poppy
def retrieve(query_vec):
    scored = tuple()
    for idx, doc in enumerate(_DOCS):
        v = embed(doc)
        scored += ((dot(query_vec, v), idx),)
    ranked = sorted(scored, reverse=True)
    out = tuple()
    for s, idx in ranked[:TOP_K]:
        out += (idx,)
    return out


@poppy
def traq(question):
    qv = embed(question)
    doc_ids = retrieve(qv)
    answers = tuple()
    for d in doc_ids:
        for j in range(GEN_PER_DOC):
            a = llm(f"answer '{question}' using {_DOCS[d]} (sample {j})",
                    max_tokens=8)
            answers += (a.split()[0],)
    clusters = {}
    for a in answers:
        clusters[a] = clusters.get(a, 0) + 1
    conformal = tuple()
    for a, n in sorted(clusters.items()):
        if n >= 2:
            conformal += (a,)
    if not conformal:
        for a, n in sorted(clusters.items()):
            conformal += (a,)
    emit(f"conformal set: {conformal}")
    return conformal


DEFAULT_INPUT = "which renewable energy source is most reliable?"
ENTRY = traq
FUNCS = [traq, retrieve]
EXTERNALS = ["llm", "embed", "dot", "emit"]


def run(question=DEFAULT_INPUT):
    OUT.clear()
    return ENTRY(question)
