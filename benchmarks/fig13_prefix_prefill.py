"""Fig. 13: prefix-aware KV reuse + bucketed chunked prefill in the
serving engine (beyond-paper; DESIGN.md §3.2, EXPERIMENTS.md §Fig. 13).

PopPy's signature workload — a burst of N parallel ``@unordered`` llm()
calls sharing a long system/context prefix (the fig5/fig11/fig12
fan-outs; LLMCompiler makes the same observation for parallel function
calling) — lands on the serving engine as one admission burst
(DESIGN.md §2.3).  Without prefix reuse the engine recomputes the full
prompt KV N times; with the radix cache
(``repro.serving.prefix_cache``) the shared prefix is prefilled once
(``LocalEngineBackend.generate_batch`` warms it) and each request only
prefills its suffix from the cached boundary, in chunks interleaved with
the live decode batch.

Two timed runs per trial on identically configured engines over the same
real (reduced-config) JAX model, plus a sequential-mode oracle:

  plain    standard sequential Python on the engine (semantic oracle)
  nocache  PopPy + batching(), prefix cache disabled — every request
           prefills its full prompt
  prefix   PopPy + batching(), radix cache + shared-prefix warm +
           chunked prefill

Every trial asserts token-exact equality of all three runs and ≡_A trace
equivalence of both PopPy runs against the oracle.  The prefill
jit-compilation count is asserted ≤ the bucketing bound
(``engine.prefill_shape_bound``) on both engines — prompts arrive in
many distinct lengths, so a recompile-per-length regression trips this
even at smoke scale.  The acceptance bar is prefix ≥3× over nocache at
N=16.

    PYTHONPATH=src:. python benchmarks/fig13_prefix_prefill.py
"""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

import jax

from repro.core import batching, equivalent, poppy, recording, \
    sequential_mode
from repro.core.ai import llm, use_backend, use_dispatcher
from repro.dispatch import Dispatcher
from repro.models import build_model
from repro.serving import LocalEngineBackend, ServingEngine

from benchmarks.common import maybe_tracing

N_FANOUT = 16
PREFIX_CHARS = 900          # ~900 shared prompt tokens (byte tokenizer)
MAX_NEW_TOKENS = 4
MAX_LEN = 1024


def make_prefix(chars: int) -> str:
    base = ("You are a careful analyst. Context: the quarterly report "
            "covers revenue, churn, hiring, and infrastructure spend "
            "across all regions. Answer tersely. ")
    s = base
    while len(s) < chars:
        s += base
    return s[:chars]


def suffixes(n: int):
    # distinct lengths on purpose: a recompile-per-length regression makes
    # the jit-compilation count track n instead of the bucket bound
    return [f"Q{i:02d}: {'x' * (i % 7)} summarize region {i}?"
            for i in range(n)]


@poppy
def fanout(prefix, queries):
    outs = tuple()
    for q in queries:
        outs += (llm(prefix + q, max_tokens=MAX_NEW_TOKENS),)
    return outs


def build(arch="stablelm-3b", *, prefix_cache: bool, prefill_chunk=256):
    from repro.configs import get_config
    # big enough that prompt ingestion is real compute (the thing the
    # radix cache saves), small enough for CPU CI
    cfg = get_config(arch).reduced().replace(
        num_layers=4, d_model=256, num_heads=8, head_dim=32, d_ff=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(13))
    engine = ServingEngine(
        model, params, max_slots=N_FANOUT, max_len=MAX_LEN,
        prefix_cache_budget=(64 << 20) if prefix_cache else 0,
        prefill_chunk=prefill_chunk)
    return engine, LocalEngineBackend(engine)


def _run_once(mode, backend, prefix, queries):
    d = Dispatcher()
    with use_backend(backend), use_dispatcher(d), recording() as tr:
        t0 = time.perf_counter()
        if mode == "plain":
            with sequential_mode():
                result = fanout(prefix, queries)
        else:
            with batching():
                result = fanout(prefix, queries)
        dt = time.perf_counter() - t0
    return result, dt, tr, d


def bench(n=N_FANOUT, *, trials=3, prefix_chars=PREFIX_CHARS):
    prefix = make_prefix(prefix_chars)
    queries = suffixes(n)
    eng_nc, be_nc = build(prefix_cache=False)
    eng_px, be_px = build(prefix_cache=True)
    # warm the compiled shapes once (bucketed: the timed runs hit the
    # same handful of compiled prefills); timing measures steady-state
    # serving, and compilation counts are asserted over the whole run
    for be in (be_nc, be_px):
        _run_once("poppy", be, prefix, queries[:2])
    eng_px.reset_prefix_cache()

    times = {"plain": [], "nocache": [], "prefix": []}
    prefix_snap = batch_snap = None
    for _ in range(trials):
        eng_px.reset_prefix_cache()  # cold radix cache every trial
        r_ref, dt, tr_ref, _ = _run_once("plain", be_nc, prefix, queries)
        times["plain"].append(dt)
        r_nc, dt, tr_nc, _ = _run_once("nocache", be_nc, prefix, queries)
        times["nocache"].append(dt)
        r_px, dt, tr_px, d_px = _run_once("prefix", be_px, prefix, queries)
        times["prefix"].append(dt)
        assert r_nc == r_ref, \
            f"nocache diverges from oracle: {r_nc!r} vs {r_ref!r}"
        assert r_px == r_ref, \
            f"prefix-cache run diverges from oracle: {r_px!r} vs {r_ref!r}"
        ok, why = equivalent(tr_ref, tr_nc)
        assert ok, f"nocache trace not ≡_A: {why}"
        ok, why = equivalent(tr_ref, tr_px)
        assert ok, f"prefix trace not ≡_A: {why}"
        px = eng_px.prefix_cache.stats()
        assert px["tokens_matched"] > 0, "radix cache never matched"
        prefix_snap = px
        snap = d_px.stats.snapshot()
        if snap["prefix"]:
            batch_snap = snap["prefix"]

    # bucketing invariant: compilations bounded by the bucket count, not
    # by the number of distinct prompt lengths seen
    for eng, label in ((eng_nc, "nocache"), (eng_px, "prefix")):
        bound = eng.prefill_shape_bound
        assert eng.prefill_compilations <= bound, (
            f"{label}: {eng.prefill_compilations} prefill compilations "
            f"exceed the bucket bound {bound} — bucketing regressed to "
            f"recompile-per-length")
    distinct_lengths = len({len(prefix) + len(q) + 1 for q in queries})
    med = {m: statistics.median(ts) for m, ts in times.items()}
    return {
        "n_fanout": n,
        "prefix_chars": prefix_chars,
        "max_new_tokens": MAX_NEW_TOKENS,
        **{f"{m}_s": t for m, t in med.items()},
        "speedup_prefix_vs_nocache": med["nocache"] / med["prefix"],
        "speedup_prefix_vs_plain": med["plain"] / med["prefix"],
        "prefill_compilations": eng_px.prefill_compilations,
        "prefill_shape_bound": eng_px.prefill_shape_bound,
        "jit_headroom": eng_px.prefill_shape_bound
        / max(eng_px.prefill_compilations, 1),
        "distinct_prompt_lengths": distinct_lengths,
        "tokens_computed_nocache": eng_nc.prefill_tokens_computed,
        "tokens_computed_prefix": eng_px.prefill_tokens_computed,
        "prefix_cache": prefix_snap,
        "prefix_batches": batch_snap,
    }


def run(out_dir="experiments/apps", trials=3, n=N_FANOUT,
        prefix_chars=PREFIX_CHARS, smoke=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, n, prefix_chars, smoke)


def _run(out_dir, trials, n, prefix_chars, smoke):
    r = bench(n, trials=trials, prefix_chars=prefix_chars)
    print(f"N={r['n_fanout']:3d}  plain {r['plain_s']:.3f}s  nocache "
          f"{r['nocache_s']:.3f}s  prefix {r['prefix_s']:.3f}s  "
          f"prefix/nocache {r['speedup_prefix_vs_nocache']:.2f}×  "
          f"(prefill tokens {r['tokens_computed_nocache']} → "
          f"{r['tokens_computed_prefix']}, "
          f"{r['prefill_compilations']} compilations ≤ "
          f"bound {r['prefill_shape_bound']} over "
          f"{r['distinct_prompt_lengths']} prompt lengths)", flush=True)
    # the speedup bar is skipped under --smoke (tiny N / one trial is
    # timing noise); equality, ≡_A, and the compilation bound were
    # asserted every trial
    if not smoke:
        assert r["speedup_prefix_vs_nocache"] >= 3.0, (
            f"acceptance: prefix-aware prefill must be ≥3× over the "
            f"no-prefix-cache engine at N={n}, got "
            f"{r['speedup_prefix_vs_nocache']:.2f}×")
        print(f"\nN={n} acceptance: "
              f"{r['speedup_prefix_vs_nocache']:.2f}× ≥ 3× ✓")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig13.json").write_text(json.dumps(r, indent=1))
    return r


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--n", type=int, default=N_FANOUT)
    ap.add_argument("--prefix-chars", type=int, default=PREFIX_CHARS)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, n=args.n, prefix_chars=args.prefix_chars,
        trace_out=args.trace_out)
