"""Fig. 14: paged KV cache — admitted users at fixed KV memory, with
zero-copy prefix sharing (beyond-paper; DESIGN.md §3.3, EXPERIMENTS.md
§Fig. 14).

PopPy's fan-out burst (N parallel ``@unordered`` llm() calls sharing a
long context) is memory-bound on the serving side: a contiguous KV cache
reserves ``max_len`` tokens per slot, so N users sharing a 200-token
prefix store it N times and the decode batch is capped by slots × slab.
The block-paged engine (``kv_layout="paged"``) stores KV in fixed-size
pages with per-slot page tables: the shared prefix occupies its pages
*once* and every user's page table references them — admission appends
page ids (``kv_admit_copies == 0``, asserted), so the same pool bytes
admit far more concurrent users.

Two engines over the same real (reduced-config) JAX model, with **equal
KV pool bytes** (asserted):

  contig   kv_layout="contiguous", max_slots=4 · max_len=256 slabs
  paged    page_size=16, num_pages=64 (= the same 1024 KV tokens),
           max_slots=16

plus a sequential-mode oracle on the contiguous engine.  Every trial
asserts token-exact equality of all three runs, ≡_A trace equivalence of
both PopPy runs, the prefill-compilation bucket bound on both engines,
the paged gather/fill shape bound, and the zero-copy counters (paged
``kv_admit_copies == 0`` while contiguous splices one copy per admit).

Metrics: ``admitted_users_ratio`` — peak concurrent decode occupancy at
fixed memory (deterministic: the contiguous engine is slot-capped while
the paged engine admits the whole burst) — and the decode step-time
ratio (reported, not gated: CPU timing noise).  The acceptance bar is
admitted ≥1.5× at N=16; smoke measures ~4×.

    PYTHONPATH=src:. python benchmarks/fig14_paged_kv.py
"""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

import jax

from repro.core import batching, equivalent, poppy, recording, \
    sequential_mode
from repro.core.ai import llm, use_backend, use_dispatcher
from repro.dispatch import Dispatcher
from repro.models import build_model
from repro.serving import LocalEngineBackend, ServingEngine
from repro.serving.prefix_cache import tree_nbytes

from benchmarks.common import maybe_tracing

N_FANOUT = 16
PREFIX_CHARS = 192          # shared prompt tokens (byte tokenizer, 1:1)
MAX_NEW_TOKENS = 20         # > N so the burst fully overlaps in decode
MAX_LEN = 256
PAGE_SIZE = 16
CONTIG_SLOTS = 4            # contiguous: 4 × 256-token slabs
PAGED_SLOTS = 16            # paged: same bytes as 64 × 16-token pages


def make_prefix(chars: int) -> str:
    base = ("System: you are a terse planner. Shared context: inventory "
            "levels, supplier lead times, and open orders for region. ")
    s = base
    while len(s) < chars:
        s += base
    return s[:chars]


def suffixes(n: int):
    return [f"Q{i:02d}: {'y' * (i % 5)} restock item {i}?"
            for i in range(n)]


@poppy
def fanout(prefix, queries):
    outs = tuple()
    for q in queries:
        outs += (llm(prefix + q, max_tokens=MAX_NEW_TOKENS),)
    return outs


def build(arch="stablelm-3b", *, layout: str):
    from repro.configs import get_config
    cfg = get_config(arch).reduced().replace(
        num_layers=4, d_model=256, num_heads=8, head_dim=32, d_ff=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(13))
    if layout == "paged":
        engine = ServingEngine(
            model, params, max_slots=PAGED_SLOTS, max_len=MAX_LEN,
            page_size=PAGE_SIZE,
            num_pages=CONTIG_SLOTS * MAX_LEN // PAGE_SIZE)
        assert engine.paged_kv
    else:
        engine = ServingEngine(
            model, params, max_slots=CONTIG_SLOTS, max_len=MAX_LEN,
            kv_layout="contiguous")
        assert not engine.paged_kv
    return engine, LocalEngineBackend(engine)


def _run_once(mode, backend, prefix, queries):
    d = Dispatcher()
    with use_backend(backend), use_dispatcher(d), recording() as tr:
        t0 = time.perf_counter()
        if mode == "plain":
            with sequential_mode():
                result = fanout(prefix, queries)
        else:
            with batching():
                result = fanout(prefix, queries)
        dt = time.perf_counter() - t0
    return result, dt, tr


def _assert_compile_bounds(eng, label):
    bound = eng.prefill_shape_bound
    assert eng.prefill_compilations <= bound, (
        f"{label}: {eng.prefill_compilations} prefill compilations exceed "
        f"the bucket bound {bound} — recompile-per-length regression")
    if eng.paged_kv:
        assert len(eng.page_op_shapes) <= eng.page_op_shape_bound, (
            f"{label}: {len(eng.page_op_shapes)} page-op shapes exceed "
            f"bound {eng.page_op_shape_bound}")


def bench(n=N_FANOUT, *, trials=3, prefix_chars=PREFIX_CHARS):
    prefix = make_prefix(prefix_chars)
    queries = suffixes(n)
    eng_ct, be_ct = build(layout="contiguous")
    eng_pg, be_pg = build(layout="paged")

    # identical KV pool bytes (the paged pool carries one extra scratch
    # page that admission can never hand out)
    ct_bytes = tree_nbytes(eng_ct.cache)
    pg_bytes = tree_nbytes(eng_pg.kv_pages) \
        * eng_pg.num_pages // (eng_pg.num_pages + 1)
    assert ct_bytes == pg_bytes, (ct_bytes, pg_bytes)

    # warm the compiled shapes once; timing/occupancy measured per trial
    for be in (be_ct, be_pg):
        _run_once("poppy", be, prefix, queries[:2])

    times = {"plain": [], "contig": [], "paged": []}
    occ, decode_ms = {"contig": [], "paged": []}, {}
    for _ in range(trials):
        for eng in (eng_ct, eng_pg):
            eng.reset_prefix_cache()  # cold radix cache every trial
        marks = {"contig": (len(eng_ct.batch_occupancy),
                            len(eng_ct.decode_step_s)),
                 "paged": (len(eng_pg.batch_occupancy),
                           len(eng_pg.decode_step_s))}
        r_ref, dt, tr_ref = _run_once("plain", be_ct, prefix, queries)
        times["plain"].append(dt)
        r_ct, dt, tr_ct = _run_once("contig", be_ct, prefix, queries)
        times["contig"].append(dt)
        r_pg, dt, tr_pg = _run_once("paged", be_pg, prefix, queries)
        times["paged"].append(dt)

        assert r_ct == r_ref, \
            f"contiguous diverges from oracle: {r_ct!r} vs {r_ref!r}"
        assert r_pg == r_ref, (
            f"paged engine not token-exact vs oracle: "
            f"{r_pg!r} vs {r_ref!r}")
        ok, why = equivalent(tr_ref, tr_ct)
        assert ok, f"contiguous trace not ≡_A: {why}"
        ok, why = equivalent(tr_ref, tr_pg)
        assert ok, f"paged trace not ≡_A: {why}"
        # zero-copy sharing: the paged engine never copies KV at admit;
        # the contiguous engine splices one copy per admitted request
        assert eng_pg.kv_admit_copies == 0, \
            f"paged engine copied KV {eng_pg.kv_admit_copies}× at admit"
        assert eng_ct.kv_admit_copies > 0
        assert eng_pg.prefix_cache.stats()["tokens_matched"] > 0, \
            "paged radix cache never matched the shared prefix"
        _assert_compile_bounds(eng_ct, "contig")
        _assert_compile_bounds(eng_pg, "paged")
        for label, eng in (("contig", eng_ct), ("paged", eng_pg)):
            o0, d0 = marks[label]
            occ[label].append(max(eng.batch_occupancy[o0:], default=0))
            decode_ms.setdefault(label, []).extend(
                eng.decode_step_s[d0:])

    med = {m: statistics.median(ts) for m, ts in times.items()}
    peak = {m: max(os) for m, os in occ.items()}
    step = {m: statistics.median(v) for m, v in decode_ms.items()}
    return {
        "n_fanout": n,
        "prefix_chars": prefix_chars,
        "max_new_tokens": MAX_NEW_TOKENS,
        "kv_pool_bytes": ct_bytes,
        **{f"{m}_s": t for m, t in med.items()},
        "admitted_users_contig": peak["contig"],
        "admitted_users_paged": peak["paged"],
        "admitted_users_ratio": peak["paged"] / max(peak["contig"], 1),
        "decode_step_contig_ms": step["contig"] * 1e3,
        "decode_step_paged_ms": step["paged"] * 1e3,
        "decode_step_ratio": step["contig"] / max(step["paged"], 1e-12),
        "kv_admit_copies_paged": eng_pg.kv_admit_copies,
        "kv_admit_copies_contig": eng_ct.kv_admit_copies,
        "prefill_compilations": eng_pg.prefill_compilations,
        "prefill_shape_bound": eng_pg.prefill_shape_bound,
        "jit_headroom": eng_pg.prefill_shape_bound
        / max(eng_pg.prefill_compilations, 1),
        "page_op_shapes": len(eng_pg.page_op_shapes),
        "page_op_shape_bound": eng_pg.page_op_shape_bound,
        "paged_stats": eng_pg.stats()["paged"],
        "prefix_cache": eng_pg.prefix_cache.stats(),
    }


def run(out_dir="experiments/apps", trials=3, n=N_FANOUT,
        prefix_chars=PREFIX_CHARS, smoke=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, n, prefix_chars, smoke)


def _run(out_dir, trials, n, prefix_chars, smoke):
    r = bench(n, trials=trials, prefix_chars=prefix_chars)
    print(f"N={r['n_fanout']:3d}  admitted users {r['admitted_users_contig']}"
          f" (contig) → {r['admitted_users_paged']} (paged) = "
          f"{r['admitted_users_ratio']:.2f}× at {r['kv_pool_bytes']} KV "
          f"bytes;  decode step {r['decode_step_contig_ms']:.2f}ms → "
          f"{r['decode_step_paged_ms']:.2f}ms;  admit copies "
          f"{r['kv_admit_copies_contig']} → {r['kv_admit_copies_paged']}  "
          f"({r['page_op_shapes']} page-op shapes ≤ "
          f"{r['page_op_shape_bound']})", flush=True)
    # equality, ≡_A, zero-copy, and both compile bounds were asserted
    # every trial; the capacity bar holds even at smoke scale because it
    # counts users, not seconds
    assert r["admitted_users_ratio"] >= 1.5, (
        f"acceptance: paged KV must admit ≥1.5× the users of the "
        f"contiguous engine at equal memory, got "
        f"{r['admitted_users_ratio']:.2f}×")
    if not smoke:
        print(f"\nN={n} acceptance: "
              f"{r['admitted_users_ratio']:.2f}× ≥ 1.5× ✓")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig14.json").write_text(json.dumps(r, indent=1))
    return r


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--n", type=int, default=N_FANOUT)
    ap.add_argument("--prefix-chars", type=int, default=PREFIX_CHARS)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, n=args.n, prefix_chars=args.prefix_chars,
        trace_out=args.trace_out)
