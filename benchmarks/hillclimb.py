import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count"
                                 "=512")

"""Perf hillclimb harness (§Perf): measure one (arch × shape) cell's
roofline terms under config overrides, logging
hypothesis → change → before → after to experiments/perf/.

    python -m benchmarks.hillclimb --arch yi-34b --shape train_4k \
        --set param_strategy=zero2 --note "ZeRO-2 weights"
"""

import argparse
import json
import time
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch.mesh import hardware_constants, make_production_mesh

HW = hardware_constants()


def measure(arch, shape_name, overrides: dict, mesh=None):
    from benchmarks.roofline import cell_costs, model_flops
    from repro.models import build_model
    import repro.configs as C

    mesh = mesh or make_production_mesh(multi_pod=False)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    # route the overridden cfg through cell_costs by registry patching
    orig = C.REGISTRY[arch]
    C.REGISTRY[arch] = cfg
    try:
        costs, _ = cell_costs(arch, shape_name, mesh)
    finally:
        C.REGISTRY[arch] = orig
    t_c = costs["flops"] / HW["peak_flops_bf16"]
    t_m = costs["bytes"] / HW["hbm_bandwidth"]
    t_x = costs["coll_bytes"] / HW["ici_link_bandwidth"]
    mf = model_flops(cfg, SHAPES[shape_name], build_model(cfg).num_params())
    bound = max(t_c, t_m, t_x)
    return {
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bound_s": bound,
        "dominant": max(("compute", t_c), ("memory", t_m),
                        ("collective", t_x), key=lambda kv: kv[1])[0],
        "roofline_fraction": (mf / (256 * HW["peak_flops_bf16"])) / bound,
        "flops_per_dev": costs["flops"], "bytes_per_dev": costs["bytes"],
        "coll_bytes_per_dev": costs["coll_bytes"],
    }


def _parse_val(v):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE")
    ap.add_argument("--note", default="")
    ap.add_argument("--baseline", action="store_true",
                    help="also measure without overrides for comparison")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _parse_val(v)

    mesh = make_production_mesh(multi_pod=False)
    rows = []
    if args.baseline or not overrides:
        t0 = time.time()
        base = measure(args.arch, args.shape, {}, mesh)
        base.update(variant="baseline", note="paper-faithful defaults",
                    measure_s=round(time.time() - t0, 1))
        rows.append(base)
        print(json.dumps(base, indent=1))
    if overrides:
        t0 = time.time()
        rec = measure(args.arch, args.shape, overrides, mesh)
        rec.update(variant=str(overrides), note=args.note,
                   measure_s=round(time.time() - t0, 1))
        rows.append(rec)
        print(json.dumps(rec, indent=1))

    out = Path("experiments/perf")
    out.mkdir(parents=True, exist_ok=True)
    log = out / f"hillclimb_{args.arch}__{args.shape}.jsonl"
    with log.open("a") as f:
        for r in rows:
            f.write(json.dumps({"arch": args.arch, "shape": args.shape,
                                **r}) + "\n")
    print(f"appended {len(rows)} rows to {log}")


if __name__ == "__main__":
    main()
