"""Fig. 9: dispatch-subsystem scaling (beyond-paper; DESIGN.md §5,
EXPERIMENTS.md §Fig. 9).

A PopPy fan-out app (N_CALLS `@unordered` llm() calls over N_UNIQUE
distinct prompts + a combine call) is driven through `repro.dispatch`
under three configurations on the deterministic simulated backend:

  single       1 replica,  concurrency cap 4, cache off   (baseline)
  routed       2 replicas, cap 4 each, least-outstanding routing + hedging
  routed_warm  routed + result cache, measured cache-warm

Every trial also runs the app under ``sequential_mode()`` against a direct
backend and asserts result equality — the dispatch layer must preserve
sequential semantics no matter the configuration (so, like fig5, every
benchmark run is also a soundness test).  The acceptance bar is
routed_warm ≥ 1.5× over single.

    PYTHONPATH=src:. python benchmarks/fig9_dispatch.py
"""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

from repro.core import poppy, sequential_mode
from repro.core.ai import use_backend, use_dispatcher, llm
from repro.dispatch import AdmissionPolicy, Dispatcher, HedgePolicy

from benchmarks.common import make_backend, maybe_tracing

N_CALLS = 24
N_UNIQUE = 8
CAP = 4          # per-replica concurrency cap


@poppy
def pipeline(n):
    summaries = tuple()
    for i in range(n):
        s = llm(f"summarize shard {i % N_UNIQUE}", max_tokens=32)
        summaries += (s,)
    combined = llm(f"combine: {summaries}", max_tokens=48)
    return combined


def _reference(scale):
    """Sequential-mode result over a direct backend — the semantic oracle."""
    with use_backend(make_backend(scale)), sequential_mode():
        return pipeline(N_CALLS)


def _dispatcher(n_replicas, *, scale, cache, hedge):
    backends = [make_backend(scale) for _ in range(n_replicas)]
    return Dispatcher(
        backends,
        policy="least_outstanding",
        cache=cache,
        admission=AdmissionPolicy(max_concurrency=CAP),
        hedge=HedgePolicy(delay_s=0.3 * scale) if hedge else None,
    )


def _timed(d, expect):
    with use_dispatcher(d):
        t0 = time.perf_counter()
        result = pipeline(N_CALLS)
        dt = time.perf_counter() - t0
    assert result == expect, (
        f"dispatch diverged from sequential_mode: {result!r} vs {expect!r}")
    return dt


def run(out_dir="experiments/apps", trials=3, scale=1.0, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, scale)


def _run(out_dir, trials, scale):
    times = {"single": [], "routed": [], "routed_warm": []}
    last_stats = {}
    for _ in range(trials):
        expect = _reference(scale)

        d1 = _dispatcher(1, scale=scale, cache=None, hedge=False)
        times["single"].append(_timed(d1, expect))

        d2 = _dispatcher(2, scale=scale, cache=None, hedge=True)
        times["routed"].append(_timed(d2, expect))

        d3 = _dispatcher(2, scale=scale, cache=True, hedge=True)
        _timed(d3, expect)                       # warm the cache (checked)
        times["routed_warm"].append(_timed(d3, expect))

        last_stats = {"single": d1.stats.snapshot(),
                      "routed": d2.stats.snapshot(),
                      "routed_warm": d3.stats.snapshot()}

    med = {k: statistics.median(v) for k, v in times.items()}
    results = {
        "n_calls": N_CALLS, "n_unique": N_UNIQUE, "cap": CAP,
        "trials": trials, "scale": scale,
        "median_s": med,
        "speedup_routed": med["single"] / med["routed"],
        "speedup_warm": med["single"] / med["routed_warm"],
        "stats": last_stats,
    }

    print(f"{N_CALLS} calls ({N_UNIQUE} unique), per-replica cap {CAP}:")
    for k in ("single", "routed", "routed_warm"):
        sp = med["single"] / med[k]
        st = last_stats[k]
        print(f"  {k:12s} {med[k] * 1e3:8.1f} ms   {sp:5.2f}×   "
              f"hit rate {st['hit_rate']:4.0%}  queue peak "
              f"{st['queue_peak']:2d}  hedge wins {st['hedge_wins']}")
        for name, bs in st["backends"].items():
            print(f"    {name}: {bs['requests']} reqs, "
                  f"p50 {bs['p50_s'] * 1e3:.0f} ms, "
                  f"p99 {bs['p99_s'] * 1e3:.0f} ms")

    assert results["speedup_warm"] >= 1.5, (
        f"cache-warm 2-replica speedup {results['speedup_warm']:.2f}× "
        "below the 1.5× acceptance bar")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig9.json").write_text(json.dumps(results, indent=1))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trace_out=args.trace_out)
