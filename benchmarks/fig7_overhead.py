"""Fig. 7: absolute execution-time overhead of PopPy's interpreter+runtime
vs plain Python, with all external calls forced @sequential (zero extracted
parallelism — isolates the λ^O interpreter cost)."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import all_apps, maybe_tracing, overhead_of


def run(out_dir="experiments/apps", trials=3, scale=1.0, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, scale)


def _run(out_dir, trials, scale):
    from benchmarks.apps import camel

    results = {}
    for name, fn, arg in all_apps():
        r = overhead_of(fn, arg, trials=trials, scale=scale)
        results[name] = r
        print(f"{name:8s} plain {r['plain_s']*1e3:8.1f} ms  "
              f"all-seq poppy {r['poppy_seq_s']*1e3:8.1f} ms  "
              f"overhead {r['overhead_s']*1e3:+7.1f} ms "
              f"({r['overhead_rel']*100:+.2f}%)", flush=True)
    # a no-LLM CaMeL program isolates pure interpreter overhead
    r = overhead_of(camel.run, "C-1", trials=trials, scale=scale)
    results["CaMeL-C-1 (no LLM)"] = r
    print(f"{'C-1':8s} plain {r['plain_s']*1e3:8.1f} ms  "
          f"all-seq poppy {r['poppy_seq_s']*1e3:8.1f} ms  "
          f"overhead {r['overhead_s']*1e3:+7.1f} ms")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig7.json").write_text(json.dumps(results, indent=1))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trace_out=args.trace_out)
