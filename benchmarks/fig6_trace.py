"""Fig. 6: a single ToT execution trace — queue→dispatch→resolve timeline
of external calls, in sequential order, rendered as ASCII + JSON."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import make_backend, maybe_tracing
from repro.core import recording
from repro.core.ai import use_backend


def run(out_dir="experiments/apps", scale=1.0, steps=2, beam=3,
        trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, scale, steps, beam)


def _run(out_dir, scale, steps, beam):
    from benchmarks.apps import tot

    old_steps, old_beam = tot.NUM_STEPS, tot.BEAM_WIDTH
    tot.NUM_STEPS, tot.BEAM_WIDTH = steps, beam
    try:
        be = make_backend(scale)
        with use_backend(be), recording() as tr:
            tot.run()
    finally:
        tot.NUM_STEPS, tot.BEAM_WIDTH = old_steps, old_beam

    evs = [e for e in tr.dispatch_order() if e.wrapped]
    t0 = min(e.t_queue for e in evs)
    horizon = max(e.t_resolve for e in evs) - t0
    width = 72
    lines = []
    rows = []
    for e in sorted(evs, key=lambda e: e.t_queue):
        q = int((e.t_queue - t0) / horizon * width)
        d = int((e.t_dispatch - t0) / horizon * width)
        r = int((e.t_resolve - t0) / horizon * width)
        bar = (" " * q + "·" * max(d - q, 0)
               + "█" * max(r - d, 1))
        label = "L" if "llm" in e.name else "P"
        lines.append(f"{label} {bar}")
        rows.append({"call": e.name, "cls": e.cls,
                     "queue_ms": (e.t_queue - t0) * 1e3,
                     "dispatch_ms": (e.t_dispatch - t0) * 1e3,
                     "resolve_ms": (e.t_resolve - t0) * 1e3})

    print(f"ToT trace ({steps} steps, beam {beam}); "
          f"· queued→dispatch, █ dispatch→resolve; L=llm P=print-like")
    for ln in lines:
        print(ln)
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig6_trace.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trace_out=args.trace_out)
