"""Fig. 15: replica fleet — routed scale-out of the serving engine, with
prefix-affinity placement (beyond-paper; DESIGN.md §3.4, EXPERIMENTS.md
§Fig. 15).

One ``ServingEngine`` scales *up* (continuous batching, paged KV, tensor
parallelism over a mesh); ``EngineFleet`` scales *out*: N replicas behind
``dispatch``'s router.  Two claims are gated here:

* **Fan-out throughput scales with replicas.**  A 16-request PopPy burst
  against one 4-slot replica drains in ~4 admission waves; against 4
  replicas (16 slots fleet-wide) it drains in ~1.  With ``step_sleep``
  modelling the device step (the asyncio waits overlap across replicas
  exactly as real device steps would), the 4-replica fleet must finish
  the identical workload ≥2.5× faster.

* **Prefix-affinity routing keeps sessions warm.**  The workload is 4
  sessions × 4 queries sharing a per-session 160-token prefix.  The
  ``prefix_affinity`` policy probes each replica's radix prefix cache
  (read-only digest) and routes to the replica already holding the
  longest prefix; ``least_outstanding`` ignores warmth.  Both fleets see
  an identical untimed priming wave (one query per session — cold-start
  traffic that spreads via the least-outstanding fallback), then the
  timed wave's per-replica ``prefix_hits / prefix_probed`` counters
  (``DispatchStats.note_route``, identical instrumentation under every
  policy) must show affinity strictly warmer.

Requests dispatch per element — no ``batching()`` — so the router places
every ``llm()`` call individually.  Every trial asserts token-exact
equality of all fleet runs against the single-replica fleet AND a
sequential-mode oracle, ≡_A trace equivalence, and the prefill-
compilation bucket bound on every replica.  A tensor-parallel leg (run
when ≥2 JAX devices are visible, e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) asserts a tp=2
engine is token-identical to the single-device engine with the same
bounded compile count.

    PYTHONPATH=src:. python benchmarks/fig15_fleet.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/fig15_fleet.py
"""

from __future__ import annotations

import asyncio
import json
import statistics
import time
from pathlib import Path

import jax

from repro.core import equivalent, poppy, recording, sequential_mode
from repro.core.ai import llm, use_dispatcher
from repro.models import build_model
from repro.serving import EngineFleet

from benchmarks.common import maybe_tracing

SESSIONS = 4
QUERIES = 4                 # timed queries per session (16 requests)
PREFIX_CHARS = 160          # per-session shared prefix (byte tok, 1:1)
MAX_NEW_TOKENS = 16
MAX_LEN = 256
SLOTS = 4                   # per replica; 1 replica ⇒ 4 admission waves
REPLICAS = 4
STEP_SLEEP = 0.012          # simulated device step; overlaps across
                            # replicas like real device steps would


def session_prefix(s: int) -> str:
    base = (f"Session {s:02d} memory: the user is planning trip {s}, "
            f"prefers rail over air, budget tier {s % 3}. Context: ")
    out = base
    while len(out) < PREFIX_CHARS:
        out += base
    return out[:PREFIX_CHARS]


def priming_prompts():
    """One cold query per session — the untimed warm-up wave that spreads
    sessions across replicas (all probes are 0, so the affinity policy
    falls back to least-outstanding) and populates each radix cache."""
    return [session_prefix(s) + "Qwarm: ok" for s in range(SESSIONS)]


def timed_prompts():
    return [session_prefix(s) + f"Q{q:02d}: next"
            for s in range(SESSIONS) for q in range(QUERIES)]


@poppy
def fanout(prompts):
    outs = tuple()
    for p in prompts:
        outs += (llm(p, max_tokens=MAX_NEW_TOKENS),)
    return outs


def build_params(arch="stablelm-3b"):
    from repro.configs import get_config
    cfg = get_config(arch).reduced().replace(
        num_layers=2, d_model=128, num_heads=8, head_dim=16, d_ff=256)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(13))


def make_fleet(model, params, *, replicas, policy):
    return EngineFleet(
        model, params, replicas=replicas, policy=policy,
        max_slots=SLOTS, max_len=MAX_LEN, page_size=16,
        step_sleep=STEP_SLEEP)


def _run_once(mode, fleet, prompts):
    with use_dispatcher(fleet.dispatcher), recording() as tr:
        t0 = time.perf_counter()
        if mode == "plain":
            with sequential_mode():
                result = fanout(prompts)
        else:
            result = fanout(prompts)
        dt = time.perf_counter() - t0
    return result, dt, tr


def _hit_counts(fleet):
    """Fleet-wide (probed, hits) from the per-replica route counters."""
    backends = fleet.stats.snapshot()["backends"]
    return (sum(b["prefix_probed"] for b in backends.values()),
            sum(b["prefix_hits"] for b in backends.values()))


def _assert_compile_bounds(fleet, label):
    for name, eng in zip(fleet.names, fleet.engines):
        bound = eng.prefill_shape_bound
        assert eng.prefill_compilations <= bound, (
            f"{label}/{name}: {eng.prefill_compilations} prefill "
            f"compilations exceed the bucket bound {bound} — "
            f"recompile-per-length regression")


def _prime(fleet, label):
    """Reset every replica's radix cache, then run the untimed priming
    wave (concurrent, so least-outstanding spreads the cold sessions)."""
    for eng in fleet.engines:
        eng.reset_prefix_cache()
    r, _, _ = _run_once("poppy", fleet, priming_prompts())
    assert len(r) == SESSIONS, f"{label}: priming wave lost requests"


def tp_leg(model, params, prompts):
    """Tensor-parallel engine ≡ single-device engine, token for token,
    with the same bounded compile count.  Needs ≥2 devices (CI sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    if jax.device_count() < 2:
        return {"status": "skipped", "reason":
                f"needs >=2 devices, have {jax.device_count()}"}
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import ByteTokenizer, ServingEngine
    tok = ByteTokenizer(model.cfg.vocab_size)
    eng1 = ServingEngine(model, params, max_slots=SLOTS, max_len=MAX_LEN)
    eng2 = ServingEngine(model, params, max_slots=SLOTS, max_len=MAX_LEN,
                         mesh=make_serving_mesh(tp=2), name="tp2")

    async def gen_all(eng):
        outs = await asyncio.gather(*(
            eng.generate(tok.encode(p), max_new_tokens=MAX_NEW_TOKENS,
                         temperature=0.0) for p in prompts))
        await eng.stop()
        return [list(o) for o in outs]

    t1 = asyncio.run(gen_all(eng1))
    t2 = asyncio.run(gen_all(eng2))
    assert t1 == t2, (
        f"tp=2 engine diverges from single-device tokens: {t2} vs {t1}")
    bound = eng2.prefill_shape_bound
    assert eng2.prefill_compilations <= bound, (
        f"tp=2 engine: {eng2.prefill_compilations} prefill compilations "
        f"exceed the bucket bound {bound}")
    return {"status": "ok", "tp": 2, "n_prompts": len(prompts),
            "prefill_compilations": eng2.prefill_compilations,
            "prefill_shape_bound": bound}


def bench(*, trials=3):
    prompts = timed_prompts()
    model, params = build_params()
    fleet1 = make_fleet(model, params, replicas=1,
                        policy="prefix_affinity")
    fleet4 = make_fleet(model, params, replicas=REPLICAS,
                        policy="prefix_affinity")
    fleet_lo = make_fleet(model, params, replicas=REPLICAS,
                          policy="least_outstanding")
    fleets = [("single", fleet1), ("fleet4", fleet4), ("lo", fleet_lo)]

    # compile-warm every replica once with the full workload shape (all
    # prompts share suffix/prefix bucket lengths, so one pass compiles
    # every prefill bucket and the decode step on each replica);
    # timing and hit rates are measured per trial after a cache reset
    for label, f in fleets:
        _prime(f, label)
        _run_once("poppy", f, prompts)

    times = {"plain": [], "single": [], "fleet4": [], "lo": []}
    rates = {"fleet4": [], "lo": []}
    for _ in range(trials):
        for label, f in fleets:
            _prime(f, label)
        r_ref, dt, tr_ref = _run_once("plain", fleet1, prompts)
        times["plain"].append(dt)
        marks = {label: _hit_counts(f) for label, f in fleets}
        r1, dt, tr1 = _run_once("poppy", fleet1, prompts)
        times["single"].append(dt)
        r4, dt, tr4 = _run_once("poppy", fleet4, prompts)
        times["fleet4"].append(dt)
        rlo, dt, trlo = _run_once("poppy", fleet_lo, prompts)
        times["lo"].append(dt)

        assert r1 == r_ref, (
            f"single-replica fleet diverges from sequential oracle: "
            f"{r1!r} vs {r_ref!r}")
        assert r4 == r_ref, (
            f"4-replica fleet not token-exact vs single replica: "
            f"{r4!r} vs {r_ref!r}")
        assert rlo == r_ref, (
            f"least-outstanding fleet not token-exact: "
            f"{rlo!r} vs {r_ref!r}")
        for label, tr in (("single", tr1), ("fleet4", tr4), ("lo", trlo)):
            ok, why = equivalent(tr_ref, tr)
            assert ok, f"{label} trace not ≡_A: {why}"
        for label, f in fleets:
            _assert_compile_bounds(f, label)
        # timed-wave hit rates from the per-replica route counters
        for label, f in (("fleet4", fleet4), ("lo", fleet_lo)):
            p0, h0 = marks[label]
            p1, h1 = _hit_counts(f)
            assert p1 - p0 == len(prompts), (
                f"{label}: expected {len(prompts)} routed probes, "
                f"got {p1 - p0}")
            rates[label].append((h1 - h0) / (p1 - p0))
        assert rates["fleet4"][-1] > rates["lo"][-1], (
            f"prefix-affinity hit rate {rates['fleet4'][-1]:.2f} not "
            f"strictly above least-outstanding {rates['lo'][-1]:.2f}")

    med = {m: statistics.median(ts) for m, ts in times.items()}
    backends = fleet4.stats.snapshot()["backends"]
    return {
        "sessions": SESSIONS,
        "queries_per_session": QUERIES,
        "n_requests": len(prompts),
        "prefix_chars": PREFIX_CHARS,
        "max_new_tokens": MAX_NEW_TOKENS,
        "replicas": REPLICAS,
        "slots_per_replica": SLOTS,
        "step_sleep_s": STEP_SLEEP,
        **{f"{m}_s": t for m, t in med.items()},
        "fleet_scaling_x4": med["single"] / med["fleet4"],
        "affinity_hit_rate": statistics.median(rates["fleet4"]),
        "least_outstanding_hit_rate": statistics.median(rates["lo"]),
        "per_replica_routed": {n: b["routed"]
                               for n, b in backends.items()},
        "per_replica_hit_tokens": {n: b["prefix_hit_tokens"]
                                   for n, b in backends.items()},
        "tp": tp_leg(model, params, prompts[:3]),
    }


def run(out_dir="experiments/apps", trials=3, smoke=False,
        trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, smoke)


def _run(out_dir, trials, smoke):
    r = bench(trials=trials)
    print(f"{r['n_requests']} requests ({r['sessions']} sessions): "
          f"1 replica {r['single_s']*1e3:.0f}ms → {r['replicas']} "
          f"replicas {r['fleet4_s']*1e3:.0f}ms = "
          f"{r['fleet_scaling_x4']:.2f}×;  warm-route rate "
          f"{r['affinity_hit_rate']:.2f} (affinity) vs "
          f"{r['least_outstanding_hit_rate']:.2f} (least-outstanding);  "
          f"tp leg: {r['tp']['status']}", flush=True)
    # equality, ≡_A, the strict affinity>least-outstanding rate gap, and
    # per-replica compile bounds were asserted every trial
    assert r["fleet_scaling_x4"] >= 2.5, (
        f"acceptance: {REPLICAS} replicas must drain the fan-out burst "
        f"≥2.5× faster than one, got {r['fleet_scaling_x4']:.2f}×")
    if not smoke:
        print(f"\nacceptance: {r['fleet_scaling_x4']:.2f}× ≥ 2.5× ✓")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig15.json").write_text(json.dumps(r, indent=1))
    return r


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, trace_out=args.trace_out)
