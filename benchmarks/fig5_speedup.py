"""Fig. 5: median speedup of PopPy over standard Python execution for the
five literature apps and the CaMeL suite (LLM-calling programs).  Every
trial also asserts result equality and ≡_A trace equivalence.

Two external-client modes:

* async (default) — components are ``async def`` clients awaited on the
  engine loop (the paper's setting).
* sync (``sync_externals=True`` / ``--sync``) — the same unmodified apps
  run against *blocking* clients (the real-world ``openai``/``requests``
  case); parallelism comes from the engine's executor-offload layer.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import all_apps, bench_app, maybe_tracing


def run(out_dir="experiments/apps", trials=3, scale=1.0, camel_count=30,
        sync_externals=False, trace_out=None):
    with maybe_tracing(trace_out):
        return _run(out_dir, trials, scale, camel_count, sync_externals)


def _run(out_dir, trials, scale, camel_count, sync_externals):
    from benchmarks.apps import camel

    label = "sync" if sync_externals else "async"
    results = {}
    for name, fn, arg in all_apps():
        r = bench_app(fn, arg, trials=trials, scale=scale,
                      sync_externals=sync_externals)
        results[name] = r
        print(f"{name:8s} plain {r['plain_s']:.3f}s  poppy "
              f"{r['poppy_s']:.3f}s  speedup {r['speedup']:.2f}×  "
              f"({r['llm_calls']} llm calls, {label} clients)", flush=True)

    camel_speedups = []
    for key in list(camel.PROGRAMS)[:camel_count]:
        if not camel.makes_llm_calls(key):
            continue  # Fig. 5 includes only LLM-calling CaMeL programs
        r = bench_app(camel.run, key, trials=max(trials - 1, 1), scale=scale,
                      sync_externals=sync_externals)
        results[f"CaMeL-{key}"] = r
        camel_speedups.append(r["speedup"])
        print(f"{key:8s} plain {r['plain_s']:.3f}s  poppy "
              f"{r['poppy_s']:.3f}s  speedup {r['speedup']:.2f}×",
              flush=True)

    speedups = [r["speedup"] for r in results.values()]
    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    summary = {"geomean": geo, "min": min(speedups), "max": max(speedups),
               "n_programs": len(speedups), "clients": label}
    print(f"\n[{label} clients] speedup geomean {geo:.2f}×  "
          f"min {summary['min']:.2f}×  max {summary['max']:.2f}×  "
          f"over {len(speedups)} programs")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = "fig5_sync.json" if sync_externals else "fig5.json"
    (out / name).write_text(json.dumps(
        {"results": results, "summary": summary}, indent=1))
    return results, summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sync", action="store_true",
                    help="run with blocking (sync-SDK) external clients")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace of the run here")
    args = ap.parse_args()
    run(trials=args.trials, sync_externals=args.sync,
        trace_out=args.trace_out)
