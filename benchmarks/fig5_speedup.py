"""Fig. 5: median speedup of PopPy over standard Python execution for the
five literature apps and the CaMeL suite (LLM-calling programs).  Every
trial also asserts result equality and ≡_A trace equivalence."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import all_apps, bench_app


def run(out_dir="experiments/apps", trials=3, scale=1.0, camel_count=30):
    from benchmarks.apps import camel

    results = {}
    for name, fn, arg in all_apps():
        r = bench_app(fn, arg, trials=trials, scale=scale)
        results[name] = r
        print(f"{name:8s} plain {r['plain_s']:.3f}s  poppy "
              f"{r['poppy_s']:.3f}s  speedup {r['speedup']:.2f}×  "
              f"({r['llm_calls']} llm calls)", flush=True)

    camel_speedups = []
    for key in list(camel.PROGRAMS)[:camel_count]:
        if not camel.makes_llm_calls(key):
            continue  # Fig. 5 includes only LLM-calling CaMeL programs
        r = bench_app(camel.run, key, trials=max(trials - 1, 1), scale=scale)
        results[f"CaMeL-{key}"] = r
        camel_speedups.append(r["speedup"])
        print(f"{key:8s} plain {r['plain_s']:.3f}s  poppy "
              f"{r['poppy_s']:.3f}s  speedup {r['speedup']:.2f}×",
              flush=True)

    speedups = [r["speedup"] for r in results.values()]
    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    summary = {"geomean": geo, "min": min(speedups), "max": max(speedups),
               "n_programs": len(speedups)}
    print(f"\nspeedup geomean {geo:.2f}×  min {summary['min']:.2f}×  "
          f"max {summary['max']:.2f}×  over {len(speedups)} programs")

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig5.json").write_text(json.dumps(
        {"results": results, "summary": summary}, indent=1))
    return results, summary


if __name__ == "__main__":
    run()
