"""Multi-agent debate (DiverseAgentEntropy-style) under PopPy: agents
answer in parallel within each round; rounds stay ordered.

    PYTHONPATH=src:. python examples/multi_agent_debate.py
"""

import time

from benchmarks.apps import dae
from repro.core import sequential_mode
from repro.core.ai import SimulatedBackend, use_backend


def main():
    backend = SimulatedBackend(base_s=0.15, per_token_s=0.01)
    with use_backend(backend):
        t0 = time.perf_counter()
        with sequential_mode():
            r1 = dae.run()
        t_plain = time.perf_counter() - t0

        t0 = time.perf_counter()
        r2 = dae.run()
        t_poppy = time.perf_counter() - t0

    assert r1 == r2
    answer, votes, distinct = r2
    print(f"consensus answer: {answer!r} ({votes}/{dae.N_AGENTS} agents, "
          f"{distinct} distinct answers)")
    print(f"standard Python : {t_plain:.2f}s")
    print(f"PopPy           : {t_poppy:.2f}s ({t_plain/t_poppy:.2f}×)")


if __name__ == "__main__":
    main()
