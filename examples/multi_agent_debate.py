"""Multi-agent debate (DiverseAgentEntropy-style) under PopPy, with
**per-agent memory effect domains** (DESIGN.md §2.2).

Each agent keeps a private history in a session-keyed ``MemoryStore``:
``memory.append(agent, ...)`` is ``@sequential`` *within that agent's
domain* — so one agent's history stays in program order while different
agents' appends (and the llm calls feeding them) all overlap.  Under the
paper's single sequence variable, every append would serialize against
every other agent's.

The example runs the debate under standard sequential Python (the
oracle) and under PopPy, checks results and per-agent memories are
identical, and reports the per-domain trace summary.

    PYTHONPATH=src:. python examples/multi_agent_debate.py
"""

import time

from repro.core import poppy, recording, sequential_mode
from repro.core.ai import MemoryStore, SimulatedBackend, llm, use_backend

N_AGENTS = 5
N_ROUNDS = 2
PERSPECTIVES = ("scientist", "historian", "engineer", "economist", "critic")

memory = MemoryStore("debate")


@poppy
def agent_turn(agent, persona, question, others):
    """One agent's turn: think, then persist the position to the agent's
    own memory domain (ordered only within this agent's history)."""
    position = llm(f"as a {persona}, answer briefly: {question} | "
                   f"others said: {others}", max_tokens=12)
    memory.append(agent, position)
    return position.split()[0] if position else "unknown"


@poppy
def debate(question):
    answers = ()
    for i in range(N_AGENTS):
        a = agent_turn(f"agent{i}", PERSPECTIVES[i], question, "")
        answers += (a,)
    for rnd in range(N_ROUNDS):
        revised = ()
        for i in range(N_AGENTS):
            others = answers[:i] + answers[i + 1:]
            a = agent_turn(f"agent{i}", PERSPECTIVES[i], question,
                           f"{others}")
            revised += (a,)
        answers = revised
    counts = {}
    for a in answers:
        counts[a] = counts.get(a, 0) + 1
    best = None
    best_n = 0
    for a, n in sorted(counts.items()):
        if n > best_n:
            best, best_n = a, n
    return (best, best_n, len(counts))


QUESTION = "what is the boiling point of water at sea level?"


def run_once(plain):
    memory.clear()
    with recording() as tr:
        t0 = time.perf_counter()
        if plain:
            with sequential_mode():
                result = debate(QUESTION)
        else:
            result = debate(QUESTION)
        dt = time.perf_counter() - t0
    return result, memory.snapshot(), dt, tr


def main():
    backend = SimulatedBackend(base_s=0.15, per_token_s=0.01)
    with use_backend(backend):
        r1, mem1, t_plain, _ = run_once(plain=True)
        r2, mem2, t_poppy, tr = run_once(plain=False)

    assert r1 == r2, (r1, r2)
    assert mem1 == mem2, "per-agent memories diverged"
    answer, votes, distinct = r2
    print(f"consensus answer: {answer!r} ({votes}/{N_AGENTS} agents, "
          f"{distinct} distinct answers)")
    for agent, history in mem2.items():
        print(f"  {agent}: {len(history)} positions, last={history[-1]!r}")
    doms = {d: n for d, n in sorted(tr.domain_summary().items())
            if d.startswith("debate:")}
    print(f"memory effect domains: {doms}")
    print(f"standard Python : {t_plain:.2f}s")
    print(f"PopPy           : {t_poppy:.2f}s ({t_plain/t_poppy:.2f}×)")


if __name__ == "__main__":
    main()
