"""Tree-of-Thoughts under PopPy (the paper's motivating application),
against the deterministic latency-modeled LLM.

    PYTHONPATH=src:. python examples/tree_of_thoughts.py
"""

import time

from benchmarks.apps import tot
from repro.core import sequential_mode
from repro.core.ai import SimulatedBackend, use_backend


def main():
    backend = SimulatedBackend(base_s=0.1, per_token_s=0.005)
    with use_backend(backend):
        t0 = time.perf_counter()
        with sequential_mode():
            r1 = tot.run()
        t_plain = time.perf_counter() - t0
        log_plain = list(tot.OUT)

        t0 = time.perf_counter()
        r2 = tot.run()
        t_poppy = time.perf_counter() - t0
        log_poppy = list(tot.OUT)

    assert r1 == r2 and log_plain == log_poppy
    print("\n".join(log_poppy[-4:]))
    print(f"\nresult: {r2}")
    print(f"standard Python : {t_plain:.2f}s")
    print(f"PopPy           : {t_poppy:.2f}s  "
          f"({t_plain/t_poppy:.2f}× — identical results and log order)")


if __name__ == "__main__":
    main()
