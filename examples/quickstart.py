"""PopPy quickstart: write sequential Python, get parallel external calls.

Part 1 uses async components (the paper's setting).  Part 2 is the
real-world case: *blocking* sync clients (classic ``openai`` /
``requests`` style) — the engine offloads them to a thread pool, so the
same sequential-looking program still parallelizes.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import poppy, sequential, sequential_mode, unordered
from repro.core.ai import SimulatedBackend, llm, llm_sync, use_backend


@sequential
def report(line):
    print(line)
    return None


@poppy
def research(topic):
    # Three independent LLM calls: PopPy dispatches them the moment their
    # prompts are ready — in parallel — while `report` stays in order.
    summary = llm(f"summarize {topic}", max_tokens=32)
    pros = llm(f"arguments in favor of {topic}", max_tokens=32)
    cons = llm(f"arguments against {topic}", max_tokens=32)
    report(f"summary: {summary}")
    report(f"pros:    {pros}")
    report(f"cons:    {cons}")
    verdict = llm(f"given pros '{pros}' and cons '{cons}', verdict on "
                  f"{topic}?", max_tokens=16)
    report(f"verdict: {verdict}")
    return verdict


@unordered
def crawl(source):
    # A blocking external — stands in for requests.get(...).text.  Sync
    # callables are dispatched on the runtime's thread-pool executor, so
    # independent calls overlap instead of serializing the event loop.
    time.sleep(0.2)
    return f"<page about {source}>"


@poppy
def brief(sources):
    # Every iteration blocks twice (crawl, then a sync LLM client) —
    # standard Python pays len(sources) × ~0.5s; PopPy overlaps them all.
    notes = tuple()
    for s in sources:
        page = crawl(s)
        notes += (llm_sync(f"key facts from {page}", max_tokens=24),)
    return llm_sync(f"write a brief from {notes}", max_tokens=48)


def main():
    backend = SimulatedBackend(base_s=0.2, per_token_s=0.01)
    with use_backend(backend):
        t0 = time.perf_counter()
        with sequential_mode():
            research("solar panels on every roof")
        t_plain = time.perf_counter() - t0

        print("\n--- now opportunistically, same program ---\n")
        t0 = time.perf_counter()
        research("solar panels on every roof")
        t_poppy = time.perf_counter() - t0

    print(f"\nstandard Python : {t_plain:.2f}s")
    print(f"PopPy           : {t_poppy:.2f}s  "
          f"({t_plain/t_poppy:.2f}× faster, same outputs, same order)")

    print("\n--- part 2: blocking sync clients (executor offload) ---\n")
    sources = ("reuters", "arxiv", "wikipedia", "hn")
    with use_backend(backend):
        t0 = time.perf_counter()
        with sequential_mode():
            out_plain = brief(sources)
        t_plain = time.perf_counter() - t0
        t0 = time.perf_counter()
        out_poppy = brief(sources)
        t_poppy = time.perf_counter() - t0
    assert out_plain == out_poppy
    print(f"standard Python : {t_plain:.2f}s  (every blocking call waits)")
    print(f"PopPy           : {t_poppy:.2f}s  "
          f"({t_plain/t_poppy:.2f}× faster — blocking calls offloaded, "
          f"same outputs)")


if __name__ == "__main__":
    main()
