"""PopPy quickstart: write sequential Python, get parallel external calls.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import poppy, sequential, sequential_mode
from repro.core.ai import SimulatedBackend, llm, use_backend


@sequential
def report(line):
    print(line)
    return None


@poppy
def research(topic):
    # Three independent LLM calls: PopPy dispatches them the moment their
    # prompts are ready — in parallel — while `report` stays in order.
    summary = llm(f"summarize {topic}", max_tokens=32)
    pros = llm(f"arguments in favor of {topic}", max_tokens=32)
    cons = llm(f"arguments against {topic}", max_tokens=32)
    report(f"summary: {summary}")
    report(f"pros:    {pros}")
    report(f"cons:    {cons}")
    verdict = llm(f"given pros '{pros}' and cons '{cons}', verdict on "
                  f"{topic}?", max_tokens=16)
    report(f"verdict: {verdict}")
    return verdict


def main():
    backend = SimulatedBackend(base_s=0.2, per_token_s=0.01)
    with use_backend(backend):
        t0 = time.perf_counter()
        with sequential_mode():
            research("solar panels on every roof")
        t_plain = time.perf_counter() - t0

        print("\n--- now opportunistically, same program ---\n")
        t0 = time.perf_counter()
        research("solar panels on every roof")
        t_poppy = time.perf_counter() - t0

    print(f"\nstandard Python : {t_plain:.2f}s")
    print(f"PopPy           : {t_poppy:.2f}s  "
          f"({t_plain/t_poppy:.2f}× faster, same outputs, same order)")


if __name__ == "__main__":
    main()
