"""End-to-end training driver: train a reduced-config model for a few
hundred steps with checkpoints and (optionally) a failure-injection drill.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-14b --steps 200
    PYTHONPATH=src python examples/train_lm.py --drill   # crash + resume
"""

import argparse
import tempfile

from repro.configs import get_config
from repro.models import build_model
from repro.training.data import LMDataset
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--drill", action="store_true",
                    help="inject a failure mid-run and resume")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    print(f"training reduced {args.arch}: "
          f"{model.num_params()/1e6:.2f}M params")

    dataset = LMDataset(vocab_size=cfg.vocab_size, batch_size=8, seq_len=64)
    optimizer = AdamW(learning_rate=cosine_schedule(
        1e-3, warmup_steps=20, total_steps=args.steps))
    with tempfile.TemporaryDirectory() as ckdir:
        tcfg = TrainConfig(
            steps=args.steps, ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=ckdir, log_every=max(args.steps // 10, 1),
            fail_at_step=args.steps // 2 if args.drill else -1)
        state, history = train(model, tcfg, dataset=dataset,
                               optimizer=optimizer)
    print(f"\nloss: {history[0][1]:.3f} → {history[-1][1]:.3f} over "
          f"{args.steps} steps"
          + (" (with one injected crash + resume)" if args.drill else ""))


if __name__ == "__main__":
    main()
