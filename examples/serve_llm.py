"""End-to-end driver: serve a small JAX model with batched requests.

A PopPy compound-AI program fans out `@unordered` llm() calls; they route
through a `repro.dispatch.Dispatcher` (admission control, result cache +
coalescing, hedged retries) into the LocalEngineBackend, whose requests
share continuous-batching decode steps on a real (reduced-config) model —
PopPy's extracted parallelism becomes decode-batch occupancy on the
engine, and the dispatcher makes the burst production-shaped.

    PYTHONPATH=src:. python examples/serve_llm.py [--arch stablelm-3b]
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.core import poppy, sequential
from repro.core.ai import llm, use_dispatcher
from repro.dispatch import AdmissionPolicy, Dispatcher, HedgePolicy
from repro.models import build_model
from repro.serving import LocalEngineBackend, ServingEngine


@sequential
def report(line):
    print(line)
    return None


@poppy
def summarize_documents(n_docs):
    summaries = tuple()
    for i in range(n_docs):
        s = llm(f"summarize document {i}", max_tokens=8)
        report(f"doc {i}: {len(s)} chars")
        summaries += (s,)
    overall = llm(f"combine: {summaries}", max_tokens=12)
    report(f"combined: {len(overall)} chars")
    return overall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--docs", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # max_len must cover the longest prompt (the combine call grows with
    # --docs) plus decode room — the engine rejects prompts that don't fit
    engine = ServingEngine(model, params, max_slots=4, max_len=256,
                           prefix_cache_budget=16 << 20, prefill_chunk=64)
    backend = LocalEngineBackend(engine)
    # production dispatch in front of the engine: admit at most max_slots
    # concurrent requests (backpressure instead of queue stampede), cache
    # identical temperature-0 prompts, hedge stragglers
    dispatcher = Dispatcher(
        [backend],
        cache=True,
        admission=AdmissionPolicy(max_concurrency=engine.max_slots),
        hedge=HedgePolicy(delay_s=30.0),
    )
    print(f"serving reduced {args.arch} "
          f"({model.num_params()/1e6:.1f}M params), "
          f"{engine.max_slots} slots\n")

    with use_dispatcher(dispatcher):
        t0 = time.perf_counter()
        summarize_documents(args.docs)
        dt = time.perf_counter() - t0

    occ = engine.batch_occupancy
    print(f"\n{args.docs}+1 LLM calls in {dt:.2f}s — "
          f"{engine.decode_tokens} tokens over {engine.steps} decode steps, "
          f"mean batch occupancy {sum(occ)/max(len(occ),1):.2f} "
          f"(max {max(occ, default=0)}): PopPy's parallel calls shared "
          "decode batches")
    es = engine.stats()
    print(f"prefill: {es['prefill_tokens_computed']} tokens computed, "
          f"{es['prefill_tokens_reused']} reused from the radix cache, "
          f"{es['prefill_compilations']} compiled shapes "
          f"(bound {es['prefill_shape_bound']})")
    print(dispatcher.stats.report())


if __name__ == "__main__":
    main()
