#!/usr/bin/env python
"""Markdown link checker for the repo docs (CI lint step).

Validates, with no third-party dependencies:

- relative links resolve to a file or directory in the repo
  (``[x](../DESIGN.md)``, ``[y](docs/BENCHMARKS.md)``);
- fragment links point at a real heading's GitHub-style anchor, both
  in-page (``[z](#refreshing)``) and cross-page
  (``[w](DESIGN.md#2-runtime)``);
- reference-style definitions (``[label]: target``) get the same checks.

External links (http/https/mailto) are *not* fetched — CI must not
depend on the network — but a bare-domain target missing its scheme is
flagged.  Checked files: README.md, DESIGN.md, EXPERIMENTS.md,
ROADMAP.md, and everything under docs/.

    python scripts/check_links.py [root]

Exit code 0 when every link resolves, 1 otherwise (one line per broken
link: ``file:line: message``).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

DOC_FILES = ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md")
DOC_DIRS = ("docs",)

# [text](target) — target may carry an optional "title"; images share the
# syntax (the leading "!" doesn't change resolution rules)
_INLINE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# [label]: target reference definitions
_REFDEF = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_FENCE = re.compile(r"^(```|~~~).*?^\1\s*$", re.MULTILINE | re.DOTALL)
_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def github_anchor(heading: str) -> str:
    """GitHub's anchor algorithm: strip markup, lowercase, drop anything
    but word chars/spaces/hyphens, spaces become hyphens."""
    text = re.sub(r"[`*_]|\[([^\]]*)\]\([^)]*\)", r"\1", heading).strip()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.lower().replace(" ", "-")


def doc_files(root: Path):
    files = [root / f for f in DOC_FILES if (root / f).is_file()]
    for d in DOC_DIRS:
        files.extend(sorted((root / d).rglob("*.md"))
                     if (root / d).is_dir() else [])
    return files


def anchors_of(path: Path, cache: dict) -> set:
    if path not in cache:
        text = _FENCE.sub("", path.read_text(encoding="utf-8"))
        cache[path] = {github_anchor(h) for h in _HEADING.findall(text)}
    return cache[path]


def check_file(path: Path, root: Path, cache: dict) -> list:
    raw = path.read_text(encoding="utf-8")
    # mask fenced code blocks (keep newlines so line numbers survive)
    text = _FENCE.sub(lambda m: re.sub(r"[^\n]", " ", m.group(0)), raw)
    errors = []

    def lineno(pos: int) -> int:
        return text.count("\n", 0, pos) + 1

    targets = [(m.start(1), m.group(1)) for m in _INLINE.finditer(text)]
    targets += [(m.start(1), m.group(1)) for m in _REFDEF.finditer(text)]
    for pos, target in targets:
        where = f"{path.relative_to(root)}:{lineno(pos)}"
        if target.startswith(_SCHEMES):
            continue
        if target.startswith("#"):
            frag, dest = target[1:], path
        else:
            base, _, frag = target.partition("#")
            dest = (path.parent / base).resolve()
            if not dest.exists():
                errors.append(f"{where}: broken link: {target!r} "
                              f"(no such file {base!r})")
                continue
            if re.match(r"^[\w.-]+\.(com|org|net|io|dev)(/|$)", base):
                errors.append(f"{where}: bare domain {base!r} — "
                              "missing https:// ?")
                continue
        if frag and dest.suffix == ".md":
            if frag.lower() not in anchors_of(dest, cache):
                errors.append(f"{where}: broken anchor: {target!r} "
                              f"(no heading anchors to #{frag!r} in "
                              f"{dest.name})")
    return errors


def main(argv) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else \
        Path(__file__).resolve().parent.parent
    files = doc_files(root)
    cache: dict = {}
    errors = []
    for f in files:
        errors.extend(check_file(f, root, cache))
    for e in errors:
        print(e)
    n_links = sum(len(_INLINE.findall(f.read_text(encoding="utf-8")))
                  for f in files)
    if errors:
        print(f"\ncheck_links: {len(errors)} broken link(s) across "
              f"{len(files)} files")
        return 1
    print(f"check_links: {len(files)} files, ~{n_links} links, all OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
